"""seacheck layer 2 (runtime lock-order / race detector).

Covers the acceptance demos: an A->B / B->A ordering inversion and a
blocking fcntl call under an in-process lock are each caught, clean
schedules produce zero findings, and the instrumentation is transparent
to Condition/RLock semantics."""

import fcntl
import os
import sys
import threading

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "tools"))

from seacheck import runtime  # noqa: E402


@pytest.fixture(autouse=True)
def _isolate():
    """Fresh graphs per test; drain before the SEACHECK=1 leg's own
    guard fixture runs, so deliberate findings don't fail the test."""
    runtime.reset()
    yield
    runtime.drain_findings()
    runtime.reset()


@pytest.fixture
def installed():
    """fcntl interposition active, restored afterwards (no-op when the
    SEACHECK=1 leg already installed it)."""
    was = runtime.installed()
    runtime.install()
    yield
    if not was:
        runtime.uninstall()


# ------------------------------------------------------------ order graph
def test_cross_site_cycle_detected():
    a = runtime.instrumented_lock("core/x.py:1")
    b = runtime.instrumented_lock("core/y.py:2")
    with a:
        with b:
            pass
    with b:
        with a:  # closes x -> y -> x
            pass
    kinds = [f.kind for f in runtime.findings()]
    assert kinds == ["lock-order-cycle"]


def test_cross_site_cycle_detected_across_threads():
    a = runtime.instrumented_lock("core/x.py:1")
    b = runtime.instrumented_lock("core/y.py:2")
    with a:
        with b:
            pass

    def inverted():
        with b:
            with a:
                pass

    t = threading.Thread(target=inverted)
    t.start()
    t.join()
    assert [f.kind for f in runtime.findings()] == ["lock-order-cycle"]


def test_same_site_abba_inversion_detected():
    # the per-key lock-pool shape: many locks born at one creation site
    a = runtime.instrumented_lock("core/seafs.py:88")
    b = runtime.instrumented_lock("core/seafs.py:88")
    with a:
        with b:
            pass
    with b:
        with a:
            pass
    kinds = [f.kind for f in runtime.findings()]
    assert kinds == ["lock-order-inversion"]


def test_consistent_order_is_clean():
    a = runtime.instrumented_lock("core/x.py:1")
    b = runtime.instrumented_lock("core/y.py:2")
    c = runtime.instrumented_lock("core/seafs.py:88")
    d = runtime.instrumented_lock("core/seafs.py:88")
    for _ in range(3):
        with a, b:  # always a -> b
            pass
        with c, d:  # same-site pair, always id-canonical? no — same ORDER
            pass
    assert runtime.findings() == []


def test_findings_deduplicate():
    a = runtime.instrumented_lock("core/x.py:1")
    b = runtime.instrumented_lock("core/y.py:2")
    with a:
        with b:
            pass
    for _ in range(5):
        with b:
            with a:
                pass
    assert len(runtime.findings()) == 1


def test_drain_and_reset_isolation():
    a = runtime.instrumented_lock("core/x.py:1")
    b = runtime.instrumented_lock("core/y.py:2")
    with a, b:
        pass
    with b, a:
        pass
    assert len(runtime.drain_findings()) == 1
    assert runtime.findings() == []
    runtime.reset()
    # after reset the old edges are gone: b -> a alone is no cycle
    with b, a:
        pass
    assert runtime.findings() == []


# ------------------------------------------------------------- semantics
def test_rlock_reentrancy_is_not_a_finding():
    r = runtime.instrumented_lock("core/seafs.py:88", rlock=True)
    with r:
        with r:
            assert r._is_owned()
    assert runtime.findings() == []


def test_condition_wait_preserves_held_count():
    r = runtime.instrumented_lock("core/telemetry.py:50", rlock=True)
    cv = threading.Condition(r)
    with cv:
        cv.wait(timeout=0.01)  # _release_save / _acquire_restore round-trip
        with r:  # still re-entrant after restore
            pass
    assert runtime.findings() == []


def test_nonblocking_acquire_failure_not_recorded():
    a = runtime.instrumented_lock("core/x.py:1")
    a.acquire()
    got = a.acquire(blocking=False)  # same thread, plain Lock: fails
    assert not got
    a.release()
    assert runtime.findings() == []


# ---------------------------------------------------------------- fcntl
def test_blocking_lockf_under_lock_is_caught(installed, tmp_path):
    a = runtime.instrumented_lock("core/x.py:1")
    fd = os.open(str(tmp_path / "f"), os.O_CREAT | os.O_RDWR)
    try:
        with a:
            fcntl.lockf(fd, fcntl.LOCK_EX)
            fcntl.lockf(fd, fcntl.LOCK_UN)
    finally:
        os.close(fd)
    kinds = [f.kind for f in runtime.findings()]
    assert kinds == ["held-across-fcntl"]


def test_nonblocking_lockf_under_lock_is_fine(installed, tmp_path):
    a = runtime.instrumented_lock("core/x.py:1")
    fd = os.open(str(tmp_path / "f"), os.O_CREAT | os.O_RDWR)
    try:
        with a:
            fcntl.lockf(fd, fcntl.LOCK_EX | fcntl.LOCK_NB)
            fcntl.lockf(fd, fcntl.LOCK_UN)
    finally:
        os.close(fd)
    assert runtime.findings() == []


def test_blocking_lockf_with_nothing_held_is_fine(installed, tmp_path):
    fd = os.open(str(tmp_path / "f"), os.O_CREAT | os.O_RDWR)
    try:
        fcntl.lockf(fd, fcntl.LOCK_EX)
        fcntl.lockf(fd, fcntl.LOCK_UN)
    finally:
        os.close(fd)
    assert runtime.findings() == []


def test_fcntl_allowlist_honoured(installed, tmp_path):
    """A caller that IS the documented journal `_locked` pairing (matched
    by file basename + function name) is exempt."""
    src = (
        "import fcntl\n"
        "def _locked(fd):\n"
        "    fcntl.lockf(fd, fcntl.LOCK_EX)\n"
        "    fcntl.lockf(fd, fcntl.LOCK_UN)\n"
    )
    ns = {}
    exec(  # compile under the allowlisted filename
        compile(src, str(tmp_path / "shared_ledger.py"), "exec"), ns
    )
    a = runtime.instrumented_lock("core/shared_ledger.py:1")
    fd = os.open(str(tmp_path / "f"), os.O_CREAT | os.O_RDWR)
    try:
        with a:
            ns["_locked"](fd)
    finally:
        os.close(fd)
    assert runtime.findings() == []


# ------------------------------------------------------------ lifecycle
def test_factory_scoping(installed, tmp_path):
    """Locks created from repro/core files are wrapped; everything else
    gets a plain lock."""
    src = "import threading\nmade = threading.Lock()\n"
    ns = {}
    exec(
        compile(src, str(tmp_path / "repro/core/fake.py"), "exec"), ns
    )
    assert isinstance(ns["made"], runtime._WrappedLock)
    here = threading.Lock()  # this test file is outside repro/core
    assert not isinstance(here, runtime._WrappedLock)


def test_dataclass_default_factory_is_instrumented(installed, tmp_path):
    """dataclass field(default_factory=threading.Lock) creations fire
    from an exec-generated <string> frame; the factory must walk past it
    to the constructing caller's file."""
    src = (
        "import threading\n"
        "from dataclasses import dataclass, field\n"
        "@dataclass\n"
        "class T:\n"
        "    _lock: object = field(default_factory=threading.Lock)\n"
        "made = T()._lock\n"
    )
    ns = {}
    exec(
        compile(src, str(tmp_path / "repro/core/fake_dc.py"), "exec"), ns
    )
    assert isinstance(ns["made"], runtime._WrappedLock)


def test_install_is_idempotent_and_reversible():
    was = runtime.installed()
    runtime.install()
    runtime.install()
    assert runtime.installed()
    assert getattr(threading.Lock, "_seacheck_original", None) is not None
    if not was:
        runtime.uninstall()
        assert not runtime.installed()
        assert getattr(threading.Lock, "_seacheck_original", None) is None


def test_real_core_modules_import_clean_under_instrumentation(installed):
    """Importing + exercising the data plane's lock-heavy paths under
    instrumentation yields zero findings (the clean-run criterion)."""
    from repro.core.telemetry import Telemetry

    t = Telemetry()
    t.record_flush(1024)
    t.local().fastpath_opens += 1
    snap = t.snapshot()
    assert snap["flushed_bytes"] == 1024
    assert runtime.drain_findings() == []
