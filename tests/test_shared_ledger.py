"""Tests for the cross-process shared capacity ledger + flusher election.

Covers this PR's acceptance criteria:
  * 8 concurrent *processes* writing into a capped root never over-commit
    it (walk-verified after drain),
  * exactly one live flusher daemon per hierarchy,
  * follower takeover within 2 heartbeats when the leader is SIGKILLed,
  * orphaned reservations of dead PIDs are expired on reconcile,
plus journal mechanics (compaction, torn-record repair), the follower
spool, idempotent ``Sea.start``, leadership release on failing ``stop()``,
per-process telemetry aggregation, and the simulator's contention model.
"""

import json
import multiprocessing as mp
import os
import signal
import time

import pytest

from repro.core import Sea, SeaConfig, SeaFS, TierSpec
from repro.core.ledger import LEDGER_DIRNAME
from repro.core.shared_ledger import SharedCapacityLedger, pid_alive
from repro.core.telemetry import Telemetry, aggregate_snapshots, load_aggregate

F = 1 << 12  # 4 KiB "max file size" used throughout

_mp = mp.get_context("fork")


def make_config(workdir: str, **kw) -> SeaConfig:
    defaults = dict(
        mount=os.path.join(workdir, "mount"),
        tiers=[
            TierSpec(
                name="tmpfs", roots=(os.path.join(workdir, "t0"),), capacity=16 * F
            ),
            TierSpec(name="pfs", roots=(os.path.join(workdir, "pfs"),), persistent=True),
        ],
        max_file_size=F,
        n_procs=8,
        shared_ledger=True,
        leader_heartbeat_s=0.2,
        ledger_reconcile_interval_s=1e9,  # isolate delta tracking from walks
    )
    defaults.update(kw)
    return SeaConfig(**defaults)


def _heartbeat_path(cfg: SeaConfig) -> str:
    return os.path.join(cfg.tiers[-1].roots[0], LEDGER_DIRNAME, "flusher.heartbeat")


def _read_heartbeat_pid(cfg: SeaConfig) -> int | None:
    try:
        with open(_heartbeat_path(cfg)) as f:
            return json.load(f).get("pid")
    except (OSError, ValueError):
        return None


def _walk_used(root: str) -> int:
    total = 0
    for dirpath, dirnames, files in os.walk(root):
        if LEDGER_DIRNAME in dirnames:
            dirnames.remove(LEDGER_DIRNAME)
        for fn in files:
            total += os.path.getsize(os.path.join(dirpath, fn))
    return total


# --------------------------------------------------------- subprocess workers
def _accounting_child(workdir: str) -> None:
    fs = SeaFS(make_config(workdir))
    fs.write_bytes(os.path.join(fs.mount, "from_child.bin"), b"c" * 700)


def _hammer_worker(workdir: str, idx: int, barrier, leader_flags) -> None:
    """One of 8 processes hammering the capped root through its own Sea."""
    cfg = make_config(workdir, flushlist=("*.out",), evictlist=("*.out",))
    sea = Sea(cfg).start()
    barrier.wait(timeout=30)  # everyone runs concurrently
    leader_flags[idx] = 1 if sea.flusher.is_leader else 0
    for j in range(12):
        data = os.urandom(F if j % 3 else F // 2)
        suffix = "out" if j % 4 == 0 else "bin"
        sea.fs.write_bytes(
            os.path.join(sea.fs.mount, f"w{idx}_{j}.{suffix}"), data
        )
    barrier.wait(timeout=30)  # hold leadership until everyone sampled/wrote
    sea.shutdown()


def _leader_candidate(workdir: str, ready, stop) -> None:
    cfg = make_config(workdir, leader_heartbeat_s=0.75)
    Sea(cfg).start()
    ready.set()
    while not stop.is_set():
        time.sleep(0.02)


def _orphan_reserver(workdir: str, root: str) -> None:
    led = SharedCapacityLedger(reconcile_interval_s=1e9)
    led.reserve(root, 12345)
    os._exit(0)  # die without releasing: the reservation is orphaned


# ------------------------------------------------------ cross-process ledger
def test_shared_ledger_cross_process_accounting(tmp_path):
    wd = str(tmp_path)
    fs = SeaFS(make_config(wd))
    fs.write_bytes(os.path.join(fs.mount, "from_parent.bin"), b"p" * 300)
    proc = _mp.Process(target=_accounting_child, args=(wd,))
    proc.start()
    proc.join(timeout=60)
    assert proc.exitcode == 0
    tier0 = fs.hierarchy.tiers[0]
    root0 = tier0.roots[0]
    # the parent's ledger replica sees the child's write without a re-walk
    # (reconcile interval is 1e9 s — only journal replay can surface it).
    # used_bytes has a documented advisory staleness of hint_window_s
    # (50 ms): on a fast machine the child finishes inside the parent's
    # hint window, so poll past it instead of racing it.
    deadline = time.monotonic() + 5
    while tier0.used_bytes(root0) != 1000 and time.monotonic() < deadline:
        time.sleep(0.02)
    assert tier0.used_bytes(root0) == 300 + 700
    got, want = fs.hierarchy.ledger.verify(root0)
    assert got == want == 1000


@pytest.mark.slow
def test_eight_processes_never_overcommit_and_one_flusher(tmp_path):
    """The PR's acceptance scenario: 8 real processes, one capped root."""
    wd = str(tmp_path)
    n_procs = 8
    barrier = _mp.Barrier(n_procs)
    leader_flags = _mp.Array("i", [0] * n_procs)
    procs = [
        _mp.Process(target=_hammer_worker, args=(wd, i, barrier, leader_flags))
        for i in range(n_procs)
    ]
    for p in procs:
        p.start()
    for p in procs:
        p.join(timeout=120)
    assert all(p.exitcode == 0 for p in procs)
    cfg = make_config(wd)
    capacity = cfg.tiers[0].capacity
    cache_root = cfg.tiers[0].roots[0]
    # walk-verified: the capped root physically holds at most its capacity
    used = _walk_used(cache_root)
    assert used <= capacity, f"over-committed: {used} > {capacity}"
    # exactly one flusher daemon was leader while all 8 ran concurrently
    assert sum(leader_flags) == 1, list(leader_flags)
    # every write landed somewhere (cache or spilled to base) — none lost
    fs = SeaFS(make_config(wd))
    for i in range(n_procs):
        for j in range(12):
            suffix = "out" if j % 4 == 0 else "bin"
            assert fs.exists(os.path.join(fs.mount, f"w{i}_{j}.{suffix}"))
    # after every Sea drained, no orphaned reservations remain
    fs.hierarchy.reconcile()
    assert fs.hierarchy.tiers[0].reserved_bytes(cache_root) == 0


@pytest.mark.slow
def test_leader_failover_within_two_heartbeats_on_sigkill(tmp_path):
    wd = str(tmp_path)
    hb = 0.75
    cfg = make_config(wd, leader_heartbeat_s=hb)
    ready_a, ready_b = _mp.Event(), _mp.Event()
    stop = _mp.Event()
    a = _mp.Process(target=_leader_candidate, args=(wd, ready_a, stop))
    a.start()
    assert ready_a.wait(timeout=30)
    deadline = time.time() + 10
    while _read_heartbeat_pid(cfg) != a.pid and time.time() < deadline:
        time.sleep(0.05)
    assert _read_heartbeat_pid(cfg) == a.pid
    b = _mp.Process(target=_leader_candidate, args=(wd, ready_b, stop))
    b.start()
    assert ready_b.wait(timeout=30)
    time.sleep(2 * hb)  # give B time to (wrongly) steal — it must not
    assert _read_heartbeat_pid(cfg) == a.pid
    os.kill(a.pid, signal.SIGKILL)
    a.join(timeout=30)
    t_kill = time.time()
    while _read_heartbeat_pid(cfg) != b.pid and time.time() - t_kill < 10:
        time.sleep(0.02)
    elapsed = time.time() - t_kill
    assert _read_heartbeat_pid(cfg) == b.pid, "follower never took over"
    assert elapsed <= 2 * hb, f"takeover took {elapsed:.2f}s > 2 heartbeats"
    stop.set()
    b.join(timeout=30)


def test_orphaned_reservation_expired_on_reconcile(tmp_path):
    root = str(tmp_path / "r")
    os.makedirs(root)
    led = SharedCapacityLedger(reconcile_interval_s=1e9)
    proc = _mp.Process(target=_orphan_reserver, args=(str(tmp_path), root))
    proc.start()
    proc.join(timeout=60)
    assert not pid_alive(proc.pid)
    assert led.reserved_bytes(root) == 12345  # orphan budget still charged
    led.reconcile(root)
    assert led.reserved_bytes(root) == 0  # crash recovery returned it
    # a live process's reservation must survive the same reconcile
    res = led.reserve(root, 777)
    led.reconcile(root)
    assert led.reserved_bytes(root) == 777
    led.release(res)


def test_two_instances_same_process_reservations_do_not_alias(tmp_path):
    """Two ledger instances in one process must mint distinct reservation
    markers — aliasing would merge (then double-free) their budgets."""
    root = str(tmp_path / "r")
    os.makedirs(root)
    a = SharedCapacityLedger(reconcile_interval_s=1e9)
    b = SharedCapacityLedger(reconcile_interval_s=1e9)
    ra = a.reserve(root, 100)
    rb = b.reserve(root, 200)
    assert ra.path != rb.path
    assert a.reserved_bytes(root) == 300
    a.release(ra)
    assert b.reserved_bytes(root) == 200
    b.release(rb)
    assert a.reserved_bytes(root) == 0


# ------------------------------------------------------------ journal mechanics
def test_journal_compacts_in_place(tmp_path):
    root = str(tmp_path / "r")
    os.makedirs(root)
    led = SharedCapacityLedger(reconcile_interval_s=1e9, compact_min_records=8)
    for i in range(200):
        led.note_written(root, f"f{i % 4}.bin", 10 + i)
    journal = os.path.join(root, LEDGER_DIRNAME, "journal")
    # 200 appends with 4 live files must have been folded away repeatedly
    assert os.path.getsize(journal) < 2048
    with open(journal) as f:
        header = f.readline().split()
    assert header[0] == "SEALEDGER1" and int(header[1]) > 1
    got, want = led.verify(root)
    assert got == sum(10 + i for i in range(196, 200))
    assert want == 0  # nothing physically on disk: pure bookkeeping ops


def test_journal_torn_record_repaired(tmp_path):
    root = str(tmp_path / "r")
    os.makedirs(root)
    # hint_window_s=0: every used_bytes must re-sync (and so repair) the
    # journal instead of serving the <50ms-old replica
    led = SharedCapacityLedger(reconcile_interval_s=1e9, hint_window_s=0.0)
    led.used_bytes(root)  # initial reconcile of the (empty) root
    led.note_written(root, "a.bin", 100)
    journal = os.path.join(root, LEDGER_DIRNAME, "journal")
    with open(journal, "ab") as f:
        f.write(b"W 999999 torn-no-newline")  # writer died mid-append
    assert led.used_bytes(root) == 100  # torn record ignored...
    with open(journal, "rb") as f:
        assert f.read().endswith(b"W 100 a.bin\n")  # ...and truncated away
    led.note_written(root, "b.bin", 50)
    assert led.used_bytes(root) == 150


def test_keys_with_spaces_and_unicode_survive_the_journal(tmp_path):
    root = str(tmp_path / "r")
    os.makedirs(root)
    led = SharedCapacityLedger(reconcile_interval_s=1e9)
    weird = "dir with space/résultat #1.bin"
    led.note_written(root, weird, 321)
    assert led.file_size(root, weird) == 321
    led.note_removed(root, weird)
    assert led.used_bytes(root) == 0


def test_wipe_resets_shared_store(tmp_path):
    cfg = make_config(str(tmp_path))
    fs = SeaFS(cfg)
    fs.write_bytes(os.path.join(fs.mount, "x.bin"), b"x" * 256)
    tier0 = fs.hierarchy.tiers[0]
    fs.wipe()
    assert tier0.used_bytes(tier0.roots[0]) == 0
    fs.write_bytes(os.path.join(fs.mount, "y.bin"), b"y" * 128)
    assert tier0.used_bytes(tier0.roots[0]) == 128


def test_scans_exclude_ledger_store(tmp_path):
    """The per-root store must be invisible to capacity scans, listdir and
    the flusher (it is bookkeeping, not cached application data)."""
    cfg = make_config(str(tmp_path), flushlist=("*",))
    sea = Sea(cfg).start()
    sea.fs.write_bytes(os.path.join(sea.fs.mount, "real.bin"), b"r" * 64)
    try:
        sea.flusher.drain()  # settle the in-flight copy (.sea_tmp) first
        tier0 = sea.fs.hierarchy.tiers[0]
        assert tier0.scan_used_bytes(tier0.roots[0]) == 64
        got, want = sea.fs.hierarchy.ledger.verify(tier0.roots[0])
        assert got == want == 64
        assert sea.fs.listdir(sea.fs.mount) == ["real.bin"]
        assert sea.flusher.scan() == 1  # only the real file, not the journal
    finally:
        sea.shutdown()


# ----------------------------------------------------------- flusher election
def test_second_instance_in_same_process_is_follower(tmp_path):
    cfg = make_config(str(tmp_path), flushlist=("*.out",), evictlist=("*.out",))
    sea1 = Sea(cfg).start()
    sea2 = Sea(cfg).start()
    try:
        assert sea1.flusher.is_leader and not sea2.flusher.is_leader
        # the follower's close events travel through the spool to the leader
        p = os.path.join(sea2.fs.mount, "routed.out")
        sea2.fs.write_bytes(p, b"s" * 96)
        deadline = time.time() + 15
        base = cfg.tiers[-1].roots[0]
        while not os.path.exists(os.path.join(base, "routed.out")):
            assert time.time() < deadline, "leader never drained the spool"
            time.sleep(0.05)
        # the base copy appears at the flush's os.replace commit, a few
        # ledger transactions BEFORE the MOVE-mode evict of the cache
        # copy runs (flush must durably commit first) — poll for the
        # eviction rather than assuming the two are atomically visible
        while sea2.fs.where(p) != "pfs":
            assert time.time() < deadline, "cache copy never evicted"
            time.sleep(0.05)
    finally:
        sea2.shutdown()
        sea1.shutdown()


def test_leadership_passes_to_next_starter_after_shutdown(tmp_path):
    cfg = make_config(str(tmp_path))
    sea1 = Sea(cfg).start()
    assert sea1.flusher.is_leader
    sea1.shutdown()
    sea2 = Sea(cfg).start()
    try:
        assert sea2.flusher.is_leader
    finally:
        sea2.shutdown()


def test_stop_releases_leadership_even_on_exception(tmp_path):
    cfg = make_config(str(tmp_path))
    sea1 = Sea(cfg).start()
    assert sea1.flusher.is_leader

    def boom(_item):
        raise RuntimeError("queue wedged")

    sea1.flusher._q.put = boom  # make stop() blow up mid-teardown
    with pytest.raises(RuntimeError):
        sea1.flusher.stop()
    # the lockfile was still released: a newcomer can lead immediately
    sea2 = Sea(cfg).start()
    try:
        assert sea2.flusher.is_leader
    finally:
        sea2.shutdown()


def test_sea_start_is_idempotent(tmp_path):
    wd = str(tmp_path)
    base = os.path.join(wd, "pfs")
    os.makedirs(base)
    with open(os.path.join(base, "stage.in"), "wb") as f:
        f.write(b"i" * 128)
    cfg = make_config(wd, prefetchlist=("*.in",))
    sea = Sea(cfg)
    sea.start()
    n_threads = len(sea.flusher._threads)
    prefetched = sea.fs.telemetry.prefetched_bytes
    assert prefetched == 128
    sea.start()  # second start: no new threads, no duplicate prefetch
    assert len(sea.flusher._threads) == n_threads
    assert sea.fs.telemetry.prefetched_bytes == prefetched
    sea.shutdown()
    sea.start()  # restart after shutdown is allowed
    assert sea.flusher._alive()
    sea.shutdown()


# ------------------------------------------------------------------ telemetry
def test_telemetry_aggregate_sums_processes(tmp_path):
    t1, t2 = Telemetry(), Telemetry()
    t1.record_io("tmpfs", written=100, seconds=0.5)
    t1.record_flush(100)
    t2.record_io("tmpfs", written=50, seconds=0.25)
    t2.record_io("pfs", read=30)
    agg = aggregate_snapshots([t1.snapshot(), t2.snapshot()])
    assert agg["tiers"]["tmpfs"]["bytes_written"] == 150
    assert agg["tiers"]["pfs"]["bytes_read"] == 30
    assert agg["flushed_bytes"] == 100
    d = str(tmp_path / "stats")
    t1.export(os.path.join(d, "1.json"))
    t2.export(os.path.join(d, "2.json"))
    agg2 = load_aggregate(d)
    assert agg2["tiers"]["tmpfs"]["bytes_written"] == 150
    assert agg2["pids"] == [os.getpid(), os.getpid()]


def test_sea_shutdown_exports_telemetry_in_shared_mode(tmp_path):
    cfg = make_config(str(tmp_path))
    sea = Sea(cfg).start()
    sea.fs.write_bytes(os.path.join(sea.fs.mount, "t.bin"), b"t" * 64)
    sea.shutdown()
    stats_dir = os.path.join(cfg.tiers[-1].roots[0], LEDGER_DIRNAME, "telemetry")
    agg = load_aggregate(stats_dir)
    assert agg["pids"] == [os.getpid()]
    assert agg["tiers"]["tmpfs"]["bytes_written"] == 64


# ---------------------------------------------------------------- configuration
def test_config_parses_shared_ledger_flags(tmp_path):
    ini = tmp_path / "sea.cfg"
    ini.write_text(
        "[sea]\n"
        f"mount = {tmp_path}/mount\n"
        "shared_ledger = true\n"
        "leader_heartbeat_s = 0.25\n"
        f"[tier.fast]\nroots = {tmp_path}/fast\n"
        f"[tier.base]\nroots = {tmp_path}/base\npersistent = true\n"
    )
    cfg = SeaConfig.from_file(str(ini))
    assert cfg.shared_ledger is True
    assert cfg.leader_heartbeat_s == 0.25
    assert isinstance(SeaFS(cfg).hierarchy.ledger, SharedCapacityLedger)


def test_config_rejects_bad_shared_settings(tmp_path):
    with pytest.raises(ValueError):
        make_config(str(tmp_path), leader_heartbeat_s=0.0)
    with pytest.raises(ValueError):
        make_config(str(tmp_path), capacity_ledger=False)  # shared needs ledger


# ------------------------------------------------------------------- simulator
def test_simulator_models_shared_ledger_contention():
    from repro.core.model import ClusterSpec, MiB, Workload
    from repro.core.simulator import Simulator

    cl = ClusterSpec(c=1, p=8)
    w = Workload(B=8, F=64 * MiB, n=6)
    sim_shared = Simulator(cl, w, "sea", shared_ledger=True, ledger_lock_s=1e-3)
    assert sim_shared.flushers_per_node == 1  # leader election: one daemon
    sim_local = Simulator(cl, w, "sea")
    assert sim_local.flushers_per_node == cl.p
    m_shared = sim_shared.run().makespan
    m_free = Simulator(cl, w, "sea", shared_ledger=True, ledger_lock_s=0.0)
    m_free = m_free.run().makespan
    assert m_shared > m_free  # lock queueing costs wall time...
    slow = Simulator(cl, w, "sea", shared_ledger=True, ledger_lock_s=1e-2)
    assert slow.run().makespan > m_shared  # ...and scales with lock length
