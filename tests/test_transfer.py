"""Data-plane tests: the chunked streaming TransferEngine and the five
copy sites routed through it (cross-mount rename, persist, flush,
prefetch, pipeline staging).

The crash-consistency tests drive the engine's fault-injection chunk
hook: a transfer killed at any chunk boundary must never leave a
partially-written destination visible to ``open``/``listdir``, must
clean up its ``.sea_tmp`` staging file, and must release every ledger
reservation it held.
"""

from __future__ import annotations

import os
import subprocess
import threading
import time

import pytest

from repro.core import (
    Sea,
    SeaConfig,
    SeaFS,
    TierSpec,
    TransferCancelled,
    TransferError,
)
from repro.core import transfer as transfer_mod

CHUNK = 64 << 10  # small chunks so every test file spans several


def make_config(tmp_path, **kw) -> SeaConfig:
    defaults = dict(
        mount=str(tmp_path / "mount"),
        tiers=[
            TierSpec(
                name="fast",
                roots=(str(tmp_path / "fast"),),
                capacity=kw.pop("fast_capacity", None),
            ),
            TierSpec(name="pfs", roots=(str(tmp_path / "pfs"),), persistent=True),
        ],
        max_file_size=1 << 20,
        transfer_chunk_bytes=CHUNK,
        transfer_retries=0,
        transfer_backoff_s=0.0,
    )
    defaults.update(kw)
    return SeaConfig(**defaults)


def tmp_files(*roots) -> list[str]:
    out = []
    for root in roots:
        for dirpath, _d, files in os.walk(root):
            out += [
                os.path.join(dirpath, f) for f in files if f.endswith(".sea_tmp")
            ]
    return out


class Boom(RuntimeError):
    pass


def kill_after(n_chunks: int):
    """Fault-injection hook: die after the n-th committed chunk."""
    state = {"n": 0}

    def hook(copied, total, tmp):
        state["n"] += 1
        if state["n"] >= n_chunks:
            raise Boom(f"injected crash at chunk {state['n']}")

    return hook


# ---------------------------------------------------------------- primitive
def test_copy_roundtrip_multichunk(tmp_path):
    fs = SeaFS(make_config(tmp_path))
    src = tmp_path / "src.bin"
    data = os.urandom(CHUNK * 3 + 17)
    src.write_bytes(data)
    dst = tmp_path / "dst.bin"
    result = fs.transfer.copy(str(src), str(dst))
    assert dst.read_bytes() == data
    assert result.nbytes == len(data)
    assert result.attempts == 1
    assert result.impl in ("copy_file_range", "sendfile", "readwrite")


def test_copy_buffered_fallback(tmp_path, monkeypatch):
    """With both zero-copy syscalls unavailable the buffered loop must
    produce identical bytes."""
    monkeypatch.setattr(transfer_mod, "_HAS_COPY_FILE_RANGE", False)
    monkeypatch.setattr(transfer_mod, "_HAS_SENDFILE", False)
    fs = SeaFS(make_config(tmp_path))
    src = tmp_path / "src.bin"
    data = os.urandom(CHUNK * 2 + 5)
    src.write_bytes(data)
    result = fs.transfer.copy(str(src), str(tmp_path / "dst.bin"))
    assert result.impl == "readwrite"
    assert (tmp_path / "dst.bin").read_bytes() == data


def test_copy_retries_then_succeeds(tmp_path):
    cfg = make_config(tmp_path, transfer_retries=2)
    fs = SeaFS(cfg)
    src = tmp_path / "src.bin"
    src.write_bytes(os.urandom(CHUNK * 2))
    attempts = {"n": 0}

    def flaky(copied, total, tmp):
        if copied <= CHUNK and attempts["n"] < 2:
            attempts["n"] += 1
            raise Boom("transient")

    fs.transfer.chunk_hook = flaky
    result = fs.transfer.copy(str(src), str(tmp_path / "dst.bin"))
    assert result.attempts == 3
    assert (tmp_path / "dst.bin").read_bytes() == src.read_bytes()
    assert not tmp_files(str(tmp_path))


def test_copy_preserves_posix_error_class(tmp_path):
    """An OSError from the copy stage keeps its class/errno (the seed's
    bare shutil.copyfile surfaced IsADirectoryError etc. through rename
    and persist), and permanent errnos are not retried."""
    cfg = make_config(tmp_path, transfer_retries=5, transfer_backoff_s=0.1)
    fs = SeaFS(cfg)
    adir = tmp_path / "iamadir"
    adir.mkdir()
    t0 = time.perf_counter()
    with pytest.raises(IsADirectoryError):
        fs.transfer.copy(str(adir), str(tmp_path / "dst.bin"))
    # fail-fast: 5 retries at 0.1s doubling backoff would take >= 3s
    assert time.perf_counter() - t0 < 1.0
    assert not tmp_files(str(tmp_path))


def test_copy_failure_cleans_tmp_and_raises(tmp_path):
    fs = SeaFS(make_config(tmp_path))
    src = tmp_path / "src.bin"
    src.write_bytes(os.urandom(CHUNK * 4))
    fs.transfer.chunk_hook = kill_after(2)
    with pytest.raises(TransferError):
        fs.transfer.copy(str(src), str(tmp_path / "dst.bin"))
    assert not (tmp_path / "dst.bin").exists()
    assert not tmp_files(str(tmp_path))


def test_cancellation_between_chunks(tmp_path):
    fs = SeaFS(make_config(tmp_path))
    src = tmp_path / "src.bin"
    src.write_bytes(os.urandom(CHUNK * 16))
    started = threading.Event()

    def stall(copied, total, tmp):
        started.set()
        time.sleep(0.01)

    fs.transfer.chunk_hook = stall
    fut = fs.transfer.submit_copy(str(src), str(tmp_path / "dst.bin"))
    assert started.wait(5)
    fut.cancel()
    with pytest.raises(TransferCancelled):
        fut.result(timeout=10)
    assert not (tmp_path / "dst.bin").exists()
    assert not tmp_files(str(tmp_path))
    fs.transfer.close()


def test_bandwidth_throttle_paces_chunks(tmp_path):
    rate = 10e6  # 10 MB/s
    cfg = make_config(
        tmp_path, transfer_bandwidth_caps={"*": rate}, transfer_chunk_bytes=128 << 10
    )
    fs = SeaFS(cfg)
    src = tmp_path / "src.bin"
    src.write_bytes(os.urandom(1 << 20))
    t0 = time.perf_counter()
    fs.transfer.copy(str(src), str(tmp_path / "dst.bin"))
    elapsed = time.perf_counter() - t0
    # 1 MiB at 10 MB/s with a ~0.5 MB burst allowance: >= ~50ms of pacing
    assert elapsed >= 0.03, elapsed


def test_disabled_engine_keeps_atomicity_and_accounting(tmp_path):
    """transfer_engine=False restores the seed's whole-file shutil copy
    but must keep the atomic commit and the ledger accounting."""
    cfg = make_config(tmp_path, transfer_engine=False, fast_capacity=1 << 20)
    sea = Sea(cfg)
    p = os.path.join(cfg.mount, "a.bin")
    with sea.fs.open(p, "wb") as f:
        f.write(b"z" * 4096)
    dst = sea.fs.persist(p)
    assert open(dst, "rb").read() == b"z" * 4096
    base = sea.fs.hierarchy.base
    assert base.used_bytes(base.roots[0]) == 4096
    assert not tmp_files(str(tmp_path))


# ---------------------------------------------------------- crash consistency
@pytest.mark.parametrize("workers", [1, 4])
def test_persist_crash_releases_reservation_no_partial(tmp_path, workers):
    cfg = make_config(tmp_path, transfer_workers=workers)
    sea = Sea(cfg)
    p = os.path.join(cfg.mount, "data/x.bin")
    with sea.fs.open(p, "wb") as f:
        f.write(os.urandom(CHUNK * 4))
    base = sea.fs.hierarchy.base
    base_root = base.roots[0]
    sea.fs.transfer.chunk_hook = kill_after(2)
    with pytest.raises(TransferError):
        sea.fs.persist(p)
    sea.fs.transfer.chunk_hook = None
    # no partial destination visible through any read path
    assert not os.path.exists(os.path.join(base_root, "data/x.bin"))
    if os.path.isdir(os.path.join(base_root, "data")):
        assert "x.bin" not in os.listdir(os.path.join(base_root, "data"))
    assert not tmp_files(base_root)
    # the admission budget was returned and no ghost bytes were recorded
    assert base.reserved_bytes(base_root) == 0
    assert base.used_bytes(base_root) == 0
    # the source is intact and still readable through the mount
    with sea.fs.open(p, "rb") as f:
        assert len(f.read()) == CHUNK * 4


@pytest.mark.parametrize("workers", [1, 4])
def test_prefetch_crash_consistency(tmp_path, workers):
    """Killed staging transfers (pool path): no partial cache copy, no
    tmp leak, no reservation leak — and surviving keys still staged."""
    cfg = make_config(
        tmp_path,
        transfer_workers=workers,
        prefetchlist=("inputs/*",),
        fast_capacity=64 << 20,
    )
    sea = Sea(cfg)
    pfs = str(tmp_path / "pfs")
    for i in range(6):
        real = os.path.join(pfs, f"inputs/f{i}.bin")
        os.makedirs(os.path.dirname(real), exist_ok=True)
        with open(real, "wb") as f:
            f.write(os.urandom(CHUNK * 2))
    calls = {"n": 0}
    lock = threading.Lock()

    def sometimes(copied, total, tmp):
        with lock:
            calls["n"] += 1
            if calls["n"] % 3 == 0:
                raise Boom("injected staging crash")

    sea.fs.transfer.chunk_hook = sometimes
    sea.flusher.prefetch()
    sea.fs.transfer.chunk_hook = None
    fast = sea.fs.hierarchy.tiers[0]
    fast_root = fast.roots[0]
    assert not tmp_files(fast_root, pfs)
    assert fast.reserved_bytes(fast_root) == 0
    # every file present in cache is complete; ledger matches the disk
    staged = 0
    for dirpath, _d, files in os.walk(fast_root):
        for fn in files:
            full = os.path.join(dirpath, fn)
            assert os.path.getsize(full) == CHUNK * 2
            staged += os.path.getsize(full)
    assert fast.used_bytes(fast_root) == staged


# ------------------------------------------------------------- rename paths
def test_rename_into_mount_atomic_commit(tmp_path):
    """Regression for the bare-copyfile cross-mount rename: the
    destination must never be visible half-written."""
    cfg = make_config(tmp_path)
    sea = Sea(cfg)
    ext = tmp_path / "outside.bin"
    data = os.urandom(CHUNK * 8)
    ext.write_bytes(data)
    dst = os.path.join(cfg.mount, "in.bin")
    roots = [r for t in sea.fs.hierarchy for r in t.roots]

    partial_sightings = []
    done = threading.Event()

    def watch():
        while not done.is_set():
            for root in roots:
                p = os.path.join(root, "in.bin")
                try:
                    size = os.path.getsize(p)
                except OSError:
                    continue
                if size != len(data):
                    partial_sightings.append(size)
            time.sleep(0.0005)

    sea.fs.transfer.chunk_hook = lambda *_a: time.sleep(0.003)
    t = threading.Thread(target=watch)
    t.start()
    try:
        sea.fs.rename(str(ext), dst)
    finally:
        done.set()
        t.join()
    sea.fs.transfer.chunk_hook = None
    assert partial_sightings == []
    assert not ext.exists()
    with sea.fs.open(dst, "rb") as f:
        assert f.read() == data


def test_rename_into_mount_crash_leaves_source(tmp_path):
    cfg = make_config(tmp_path)
    sea = Sea(cfg)
    ext = tmp_path / "outside.bin"
    ext.write_bytes(os.urandom(CHUNK * 4))
    dst = os.path.join(cfg.mount, "in.bin")
    sea.fs.transfer.chunk_hook = kill_after(2)
    with pytest.raises(TransferError):
        sea.fs.rename(str(ext), dst)
    sea.fs.transfer.chunk_hook = None
    assert ext.exists()  # move semantics: source only removed after commit
    assert not sea.fs.exists(dst)
    roots = [r for t in sea.fs.hierarchy for r in t.roots]
    assert not tmp_files(*roots)
    for t_ in sea.fs.hierarchy:
        for r in t_.roots:
            assert t_.reserved_bytes(r) == 0


def test_rename_missing_source_posix_error(tmp_path):
    cfg = make_config(tmp_path, fast_capacity=4 << 20, max_file_size=1 << 18)
    sea = Sea(cfg)
    for _ in range(3):
        with pytest.raises(FileNotFoundError):
            sea.fs.rename(
                str(tmp_path / "nope.bin"), os.path.join(sea.fs.mount, "x")
            )
    # the admission reservation taken for the destination must not leak
    # when the source turns out to be unreadable (repeated failed renames
    # would otherwise permanently exhaust a capped root's budget)
    fast = sea.fs.hierarchy.tiers[0]
    assert fast.reserved_bytes(fast.roots[0]) == 0


def test_rename_into_mount_drops_stale_slower_replica(tmp_path):
    """An inbound rename onto a key with a persisted base copy must not
    leave the old content to resurface after the cache copy is evicted."""
    cfg = make_config(tmp_path)
    sea = Sea(cfg)
    p = os.path.join(cfg.mount, "k.bin")
    with sea.fs.open(p, "wb") as f:
        f.write(b"old" * 1000)
    sea.fs.persist(p)  # base replica now holds the old content
    ext = tmp_path / "new.bin"
    ext.write_bytes(b"new" * 2000)
    sea.fs.rename(str(ext), p)
    base_real = os.path.join(sea.fs.hierarchy.base.roots[0], "k.bin")
    assert not os.path.exists(base_real)  # stale base replica dropped
    # evicting the cache copy must not resurrect the old bytes
    fast = sea.fs.hierarchy.tiers[0]
    real = fast.locate("k.bin")
    assert real is not None
    with sea.fs.open(p, "rb") as f:
        assert f.read() == b"new" * 2000


def test_rename_into_mount_ledger_admission(tmp_path):
    """The destination root's ledger sees the renamed-in bytes (the seed
    recorded them only after the copy, with no in-flight reservation)."""
    cfg = make_config(tmp_path, fast_capacity=4 << 20, max_file_size=1 << 18)
    sea = Sea(cfg)
    ext = tmp_path / "outside.bin"
    ext.write_bytes(os.urandom(CHUNK * 3))
    sea.fs.rename(str(ext), os.path.join(cfg.mount, "in.bin"))
    fast = sea.fs.hierarchy.tiers[0]
    assert fast.used_bytes(fast.roots[0]) == CHUNK * 3
    assert fast.reserved_bytes(fast.roots[0]) == 0


def test_rename_out_of_mount_crash_keeps_sea_copy(tmp_path):
    cfg = make_config(tmp_path)
    sea = Sea(cfg)
    p = os.path.join(cfg.mount, "keep.bin")
    with sea.fs.open(p, "wb") as f:
        f.write(os.urandom(CHUNK * 4))
    out = tmp_path / "exported.bin"
    sea.fs.transfer.chunk_hook = kill_after(2)
    with pytest.raises(TransferError):
        sea.fs.rename(p, str(out))
    sea.fs.transfer.chunk_hook = None
    assert not out.exists()
    assert sea.fs.exists(p)
    sea.fs.rename(p, str(out))  # now it works
    assert out.exists() and not sea.fs.exists(p)


def test_rename_out_creates_destination_dir(tmp_path):
    sea = Sea(make_config(tmp_path))
    p = os.path.join(sea.fs.mount, "exp.bin")
    with sea.fs.open(p, "wb") as f:
        f.write(b"e" * 4096)
    out = tmp_path / "newdir" / "sub" / "exp.bin"  # parents don't exist yet
    sea.fs.rename(p, str(out))
    assert out.read_bytes() == b"e" * 4096


def test_flush_failure_counted_and_drain_raises(tmp_path):
    """A flush that exhausts its retries must not kill the worker thread,
    must be visible in telemetry, and a drain that ends with the file
    still unflushed must RAISE (shutdown durability contract)."""
    cfg = make_config(tmp_path, flushlist=("*",))
    sea = Sea(cfg)
    sea.flusher.start()
    sea.fs.transfer.chunk_hook = kill_after(1)
    p = os.path.join(cfg.mount, "doomed.bin")
    with sea.fs.open(p, "wb") as f:
        f.write(b"d" * (CHUNK * 2))
    with pytest.raises(TransferError):
        sea.flusher.drain()
    assert sea.fs.telemetry.snapshot()["flush_failures"] >= 1
    # the worker survived: clearing the fault lets the flush succeed
    sea.fs.transfer.chunk_hook = None
    sea.flusher.drain()
    base_root = sea.fs.hierarchy.base.roots[0]
    assert os.path.exists(os.path.join(base_root, "doomed.bin"))
    sea.flusher.stop()


# ------------------------------------------------------------- flush freshness
def flush_and_read(sea, key):
    sea.flusher.process(key)
    base_root = sea.fs.hierarchy.base.roots[0]
    with open(os.path.join(base_root, key), "rb") as f:
        return f.read()


def test_flush_freshness_nanosecond_rewrite(tmp_path):
    """Regression for the coarse-mtime freshness check: a source
    rewritten within the same whole-second tick must still re-flush."""
    cfg = make_config(tmp_path, flushlist=("*",))
    sea = Sea(cfg)
    p = os.path.join(cfg.mount, "r.bin")
    with sea.fs.open(p, "wb") as f:
        f.write(b"a" * 4096)
    key = sea.fs.key_of(p)
    assert flush_and_read(sea, key) == b"a" * 4096
    src_real = sea.fs.resolve_read(key)[1]
    dst_real = os.path.join(sea.fs.hierarchy.base.roots[0], key)
    # copystat parity: the committed base copy carries the source's mtime
    assert os.stat(dst_real).st_mtime_ns == os.stat(src_real).st_mtime_ns
    # rewrite the source 1ns later — a float-seconds getmtime compare
    # (the seed check) rounds this away and never re-flushes
    with sea.fs.open(p, "wb") as f:
        f.write(b"b" * 4096)
    st = os.stat(dst_real)
    os.utime(src_real, ns=(st.st_atime_ns, st.st_mtime_ns + 1))
    assert flush_and_read(sea, key) == b"b" * 4096


def test_flush_freshness_size_mismatch_same_mtime(tmp_path):
    """Same mtime but different size (clock stuck / coarse filesystem):
    the size compare must force the re-flush."""
    cfg = make_config(tmp_path, flushlist=("*",))
    sea = Sea(cfg)
    p = os.path.join(cfg.mount, "s.bin")
    with sea.fs.open(p, "wb") as f:
        f.write(b"a" * 4096)
    key = sea.fs.key_of(p)
    flush_and_read(sea, key)
    with sea.fs.open(p, "wb") as f:
        f.write(b"c" * 8192)
    src_real = sea.fs.resolve_read(key)[1]
    dst_real = os.path.join(sea.fs.hierarchy.base.roots[0], key)
    st = os.stat(dst_real)
    os.utime(src_real, ns=(st.st_atime_ns, st.st_mtime_ns))  # identical mtime
    assert flush_and_read(sea, key) == b"c" * 8192


# ----------------------------------------------------------- orphan handling
def dead_pid() -> int:
    proc = subprocess.Popen(["true"])
    proc.wait()
    return proc.pid


def test_orphan_reaping_rules(tmp_path):
    from repro.core.transfer import _HOST

    fs = SeaFS(make_config(tmp_path))
    root = str(tmp_path / "fast")
    dead = os.path.join(root, f"a.bin.{_HOST}.{dead_pid()}.3.sea_tmp")
    alive = os.path.join(root, f"b.bin.{_HOST}.1.7.sea_tmp")  # pid 1 lives
    other_node = os.path.join(root, "d.bin.nodeX.1234.0.sea_tmp")
    fresh_unparseable = os.path.join(root, "c.bin.sea_tmp")
    for p in (dead, alive, other_node, fresh_unparseable):
        with open(p, "wb") as f:
            f.write(b"partial")
    assert fs.transfer.sweep_orphans(root) == 1
    assert not os.path.exists(dead)
    assert os.path.exists(alive)       # live pid on THIS host
    assert os.path.exists(other_node)  # foreign host: age grace only
    assert os.path.exists(fresh_unparseable)  # too young to condemn
    assert fs.telemetry.snapshot()["transfer_orphans_reaped"] == 1


def test_orphan_age_grace_reaps_stale_foreign_tmp(tmp_path):
    from repro.core import transfer as tm

    fs = SeaFS(make_config(tmp_path))
    root = str(tmp_path / "fast")
    stale = os.path.join(root, "e.bin.nodeX.1234.0.sea_tmp")
    with open(stale, "wb") as f:
        f.write(b"partial")
    old = time.time() - tm.ORPHAN_GRACE_S - 10
    os.utime(stale, (old, old))
    assert fs.transfer.maybe_reap_orphan(stale)
    assert not os.path.exists(stale)


def test_orphan_reap_rules_for_live_local_pid(tmp_path):
    """A live same-host pid protects a FRESH staging file (in-flight
    transfers keep their tmp mtime fresh), but not a stale one — the pid
    may have been recycled after the real owner crashed, and the dead
    bytes would otherwise occupy the root invisibly forever (capacity
    scans skip .sea_tmp)."""
    from repro.core import transfer as tm

    fs = SeaFS(make_config(tmp_path))
    root = str(tmp_path / "fast")
    fresh = os.path.join(root, f"f.bin.{tm._HOST}.{os.getpid()}.9.sea_tmp")
    stale = os.path.join(root, f"g.bin.{tm._HOST}.{os.getpid()}.10.sea_tmp")
    for p in (fresh, stale):
        with open(p, "wb") as f:
            f.write(b"partial")
    old = time.time() - tm.ORPHAN_GRACE_S - 10
    os.utime(stale, (old, old))
    assert not fs.transfer.maybe_reap_orphan(fresh)
    assert fs.transfer.maybe_reap_orphan(stale)
    assert os.path.exists(fresh) and not os.path.exists(stale)


def test_lru_walk_skips_inflight_tmp(tmp_path):
    """LRU room-making must never delete an in-flight staging file (and
    must not treat it as an evictable key)."""
    from repro.core.transfer import _HOST

    cfg = make_config(tmp_path, lru_evict=True)
    sea = Sea(cfg)
    fast_root = str(tmp_path / "fast")
    inflight = os.path.join(fast_root, f"live.bin.{_HOST}.1.0.sea_tmp")
    with open(inflight, "wb") as f:
        f.write(b"x" * 4096)
    for name in ("old.bin", "new.bin"):
        with sea.fs.open(os.path.join(cfg.mount, name), "wb") as f:
            f.write(b"o" * 8192)
    assert sea.fs._lru_make_room()  # evicted the closed KEEP-mode files
    assert not os.path.exists(os.path.join(fast_root, "old.bin"))
    assert os.path.exists(inflight)  # survived the LRU walk untouched


def test_flusher_scan_ignores_tmp_keys(tmp_path):
    cfg = make_config(tmp_path, flushlist=("*",))
    sea = Sea(cfg)
    fast_root = str(tmp_path / "fast")
    with open(os.path.join(fast_root, "ghost.bin.1.0.sea_tmp"), "wb") as f:
        f.write(b"partial")
    assert sea.flusher.scan() == 0
    base_root = sea.fs.hierarchy.base.roots[0]
    assert not tmp_files(base_root)


# -------------------------------------------------------------- prefetch pool
def test_prefetch_staged_bytes_accounted_and_admission_capped(tmp_path):
    """Prefetch staging reserves before copying: a capped cache tier can
    never be over-committed by concurrent staging, and staged bytes are
    ledger-visible."""
    n, size = 6, 64 << 10
    cap = int(2.5 * size) + (1 << 20)  # room for ~2 files + headroom
    cfg = make_config(
        tmp_path,
        prefetchlist=("inputs/*",),
        fast_capacity=cap,
        max_file_size=1 << 18,
        transfer_workers=4,
    )
    sea = Sea(cfg)
    pfs = str(tmp_path / "pfs")
    for i in range(n):
        real = os.path.join(pfs, f"inputs/f{i}.bin")
        os.makedirs(os.path.dirname(real), exist_ok=True)
        with open(real, "wb") as f:
            f.write(os.urandom(size))
    sea.flusher.prefetch()
    fast = sea.fs.hierarchy.tiers[0]
    fast_root = fast.roots[0]
    on_disk = sum(
        os.path.getsize(os.path.join(dp, fn))
        for dp, _d, files in os.walk(fast_root)
        for fn in files
    )
    assert on_disk <= cap
    assert fast.used_bytes(fast_root) == on_disk
    assert fast.reserved_bytes(fast_root) == 0
    assert sea.fs.telemetry.snapshot()["prefetched_bytes"] == on_disk


# ------------------------------------------------------------------ telemetry
def test_transfer_telemetry_pairs(tmp_path):
    cfg = make_config(tmp_path, flushlist=("*",))
    sea = Sea(cfg)
    p = os.path.join(cfg.mount, "t.bin")
    with sea.fs.open(p, "wb") as f:
        f.write(b"x" * (CHUNK + 1))
    key = sea.fs.key_of(p)
    sea.flusher.process(key)
    snap = sea.fs.telemetry.snapshot()
    assert snap["transfers"]["fast->pfs"]["nbytes"] == CHUNK + 1
    assert snap["transfers"]["fast->pfs"]["files"] == 1
    assert sea.fs.telemetry.transfer_rate_bps("fast->pfs") > 0

    from repro.core.telemetry import aggregate_snapshots

    agg = aggregate_snapshots([snap, snap])
    assert agg["transfers"]["fast->pfs"]["nbytes"] == 2 * (CHUNK + 1)


# ------------------------------------------------------------------- config
def test_config_validation():
    base = dict(
        mount="/tmp/sea_cfg_test/mount",
        tiers=[
            TierSpec(name="a", roots=("/tmp/sea_cfg_test/a",)),
            TierSpec(name="b", roots=("/tmp/sea_cfg_test/b",), persistent=True),
        ],
    )
    with pytest.raises(ValueError):
        SeaConfig(**base, transfer_workers=0)
    with pytest.raises(ValueError):
        SeaConfig(**base, transfer_chunk_bytes=0)
    with pytest.raises(ValueError):
        SeaConfig(**base, transfer_retries=-1)
    with pytest.raises(ValueError):
        SeaConfig(**base, transfer_bandwidth_caps={"a->b": 0})


# ------------------------------------------------------------------ simulator
def test_simulator_overlap_model_reduces_flush_tail():
    """More transfer workers must not lengthen the flush tail, and with a
    per-stream cap binding, overlap strictly shortens it."""
    from repro.core.model import ClusterSpec, MiB, Workload
    from repro.core.simulator import Simulator

    cl = ClusterSpec(c=1, p=2, g=1)
    w = Workload(n=4, F=256 * MiB, B=8)
    caps = {"*": 50e6}  # one stream alone cannot saturate the backend

    def tail(workers):
        sim = Simulator(
            cl, w, "sea-flushall",
            transfer_workers=workers, transfer_bandwidth_caps=caps,
        )
        return sim.run().makespan

    t1, t4 = tail(1), tail(4)
    assert t4 < t1
