"""seacheck — mechanical enforcement of Sea's data-plane contracts.

Two layers:

* ``seacheck lint`` (``seacheck.cli``): AST invariant rules over
  ``src/repro`` — reservation pairing, atomic-commit discipline,
  invalidation completeness, telemetry drift, lock discipline. Pure
  stdlib; runs as a blocking CI gate.
* ``seacheck.runtime``: opt-in (``SEACHECK=1``) lock-order / race
  detector that instruments ``threading.Lock``/``RLock`` creation in
  ``repro.core`` and reports ordering cycles and held-across-``fcntl``
  acquisitions as pytest failures.

See ``docs/SEACHECK.md`` for rules, suppressions, and the baseline
workflow.
"""

from __future__ import annotations

__all__ = ["__version__"]
__version__ = "0.1"
