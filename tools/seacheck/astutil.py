"""Small AST conveniences shared by the rules (stdlib ``ast`` only)."""

from __future__ import annotations

import ast


def annotate_parents(tree: ast.AST) -> None:
    """Attach ``_sea_parent`` links so rules can walk upward."""
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            child._sea_parent = node  # type: ignore[attr-defined]


def parent(node: ast.AST) -> ast.AST | None:
    return getattr(node, "_sea_parent", None)


def ancestors(node: ast.AST):
    cur = parent(node)
    while cur is not None:
        yield cur
        cur = parent(cur)


def qualname(node: ast.AST) -> str:
    """Dotted qualname of the enclosing def/class chain (``<module>`` at
    module level)."""
    names = []
    cur: ast.AST | None = node
    while cur is not None:
        if isinstance(
            cur, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)
        ):
            names.append(cur.name)
        cur = parent(cur)
    return ".".join(reversed(names)) or "<module>"


def enclosing_function(
    node: ast.AST,
) -> ast.FunctionDef | ast.AsyncFunctionDef | None:
    for anc in [node, *ancestors(node)]:
        if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return anc
    return None


def call_name(call: ast.Call) -> str:
    """Trailing name of the called function: ``a.b.c(...)`` -> ``c``."""
    f = call.func
    if isinstance(f, ast.Attribute):
        return f.attr
    if isinstance(f, ast.Name):
        return f.id
    return ""


def dotted_source(node: ast.AST) -> str:
    """Best-effort source of a (possibly dotted) expression."""
    try:
        return ast.unparse(node)
    except Exception:  # pragma: no cover - malformed synthetic nodes
        return ""


def names_in(node: ast.AST) -> set[str]:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def in_with_matching(node: ast.AST, tokens: tuple[str, ...]) -> bool:
    """Is ``node`` lexically inside a ``with`` statement whose context
    expression source contains one of ``tokens``?"""
    for anc in ancestors(node):
        if isinstance(anc, (ast.With, ast.AsyncWith)):
            for item in anc.items:
                src = dotted_source(item.context_expr)
                if any(tok in src for tok in tokens):
                    return True
    return False


def string_fragments(node: ast.AST) -> list[str]:
    """Every literal string fragment reachable inside an expression
    (constants and f-string parts)."""
    out = []
    for n in ast.walk(node):
        if isinstance(n, ast.Constant) and isinstance(n.value, str):
            out.append(n.value)
    return out


def identifier_fragments(node: ast.AST) -> list[str]:
    """Every Name id / Attribute attr inside an expression."""
    out = []
    for n in ast.walk(node):
        if isinstance(n, ast.Name):
            out.append(n.id)
        elif isinstance(n, ast.Attribute):
            out.append(n.attr)
    return out
