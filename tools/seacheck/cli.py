"""``seacheck lint`` — run the invariant rules over a source tree.

Pure stdlib (``ast`` + ``json``): the CI lint job needs no third-party
installs and never imports the checked code.

Usage::

    PYTHONPATH=src:tools python -m seacheck lint src/repro
    python -m seacheck lint --update-baseline src/repro   # accept findings
"""

from __future__ import annotations

import argparse
import ast
import json
import os
import sys

from .rules import ALL_RULES
from .violations import (
    RULES,
    SourceFile,
    Violation,
    filter_baselined,
    load_baseline,
)
from .astutil import annotate_parents

DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__), "baseline.json")


def iter_py_files(paths: list[str]):
    for p in paths:
        if os.path.isfile(p):
            yield p
            continue
        for dirpath, dirnames, filenames in os.walk(p):
            dirnames[:] = [
                d for d in dirnames if d not in ("__pycache__", ".git")
            ]
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    yield os.path.join(dirpath, fn)


def relpath(path: str, root: str) -> str:
    rel = os.path.relpath(os.path.abspath(path), root)
    return rel.replace(os.sep, "/")


def lint_paths(
    paths: list[str], *, root: str | None = None, rules=ALL_RULES
) -> list[Violation]:
    """All unsuppressed violations over ``paths`` (baseline NOT applied)."""
    root = root or os.getcwd()
    out: list[Violation] = []
    for path in iter_py_files(paths):
        out.extend(lint_file(path, root=root, rules=rules))
    return out


def lint_file(path: str, *, root: str, rules=ALL_RULES) -> list[Violation]:
    try:
        with open(path, encoding="utf-8") as f:
            source = f.read()
    except OSError as e:
        print(f"seacheck: cannot read {path}: {e}", file=sys.stderr)
        return []
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [
            Violation(
                "parse-error",
                relpath(path, root),
                e.lineno or 1,
                "<module>",
                f"syntax error: {e.msg}",
            )
        ]
    annotate_parents(tree)
    sf = SourceFile(path=relpath(path, root), source=source)
    out: list[Violation] = []
    for rule in rules:
        out.extend(rule.check(sf, tree))
    return out


def _cmd_lint(args: argparse.Namespace) -> int:
    violations = lint_paths(args.paths, root=args.root)
    baseline = {} if args.no_baseline else load_baseline(args.baseline)
    fresh, stale = filter_baselined(violations, baseline)
    if args.update_baseline:
        entries = [
            {
                "rule": v.rule,
                "path": v.path,
                "symbol": v.symbol,
                "reason": "TODO: justify or fix",
            }
            for v in sorted(fresh, key=lambda v: v.key())
        ]
        entries.extend(
            {"rule": r, "path": p, "symbol": s, "reason": baseline[(r, p, s)]}
            for (r, p, s) in sorted(baseline)
            if (r, p, s) not in stale
        )
        with open(args.baseline, "w") as f:
            json.dump(sorted(entries, key=lambda e: (e["path"], e["rule"])), f,
                      indent=2)
            f.write("\n")
        print(f"seacheck: baseline updated ({len(entries)} entries)")
        return 0
    for key in stale:
        print(
            "seacheck: warning: stale baseline entry "
            f"{key[0]} {key[1]} {key[2]} (fixed? prune it)",
            file=sys.stderr,
        )
    for v in sorted(fresh, key=lambda v: (v.path, v.line)):
        print(v.render())
    n_base = len(violations) - len(fresh)
    if fresh:
        print(
            f"seacheck: {len(fresh)} violation(s) "
            f"({n_base} baselined, {len(RULES)} rules)"
        )
        return 1
    print(
        f"seacheck: clean ({n_base} baselined accepted violation(s), "
        f"{len(RULES)} rules)"
    )
    return 0


def _cmd_rules(_args: argparse.Namespace) -> int:
    for rule_id, doc in sorted(RULES.items()):
        print(f"{rule_id}: {doc}")
    return 0


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(prog="seacheck")
    sub = parser.add_subparsers(dest="cmd", required=True)
    lint = sub.add_parser("lint", help="run the invariant rules")
    lint.add_argument("paths", nargs="+")
    lint.add_argument("--root", default=os.getcwd())
    lint.add_argument("--baseline", default=DEFAULT_BASELINE)
    lint.add_argument("--no-baseline", action="store_true")
    lint.add_argument(
        "--update-baseline",
        action="store_true",
        help="accept current findings into the baseline (reasons: TODO)",
    )
    lint.set_defaults(func=_cmd_lint)
    rules = sub.add_parser("rules", help="list rules")
    rules.set_defaults(func=_cmd_rules)
    args = parser.parse_args(argv)
    return args.func(args)
