"""Known-bad: ad-hoc counter increment outside Telemetry.record_* (rule d,
non-telemetry-module side). Linted as if it were a data-plane module."""


class Engine:
    def copy(self, nbytes):
        # bypasses the telemetry lock and the COUNTERS registry
        self.telemetry.flushed_bytes += nbytes
