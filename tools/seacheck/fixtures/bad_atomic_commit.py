"""Known-bad: atomic-commit violations (rule b)."""

import os
import shutil

import numpy as np


def bare_write_to_tier_path(real, data):
    # the destination is resolvable at byte 0: a reader races the write
    with open(real, "wb") as f:
        f.write(data)


def shutil_copy_bypasses_engine(src, dst):
    shutil.copyfile(src, dst)


def np_save_in_place(real, arr):
    np.save(real, arr)


def sanctioned_tmp_replace(real, data):
    tmp = f"{real}.{os.getpid()}.sea_tmp"
    with open(tmp, "wb") as f:
        f.write(data)
    os.replace(tmp, real)


def mount_api_is_fine(fs, path, data):
    with fs.open(path, "wb") as f:
        f.write(data)


def reads_are_fine(real):
    with open(real, "rb") as f:
        return f.read()
