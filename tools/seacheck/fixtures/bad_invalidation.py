"""Known-bad: invalidation-completeness violations (rule c).

Linted as if it were ``src/repro/core/seafs.py`` (the rule is scoped to
the resolver-owning modules); ``_fed_unpublish`` below makes the module
federation-aware, so compliant sites need resolver AND registry calls.
"""

import os


class BadFS:
    def evict_without_invalidation(self, key, real):
        # the resolver keeps serving the dead path; peers keep pulling it
        os.remove(real)

    def evict_without_fed(self, key, real):
        os.remove(real)
        self.resolver.invalidate(key)

    def evict_correctly(self, key, real):
        os.remove(real)
        self.resolver.invalidate(key)
        self._fed_unpublish(key)

    def machinery_is_exempt(self, path):
        os.replace(path + ".tmp", path + ".heartbeat")

    def _fed_unpublish(self, key):
        raise NotImplementedError
