"""Known-bad: lock-discipline violations (rule e).

Linted as if it were ``src/repro/core/seafs.py``: ``_open_counts`` etc.
are documented as guarded by ``self._lock``.
"""

import threading


class BadFS:
    def __init__(self):
        self._lock = threading.RLock()
        self._open_counts = {}
        self._access_clock = {}

    def unlocked_mutation(self, key):
        self._open_counts[key] = self._open_counts.get(key, 0) + 1

    def unlocked_method_mutation(self, key):
        self._open_counts.pop(key, None)

    def locked_mutation(self, key):
        with self._lock:
            self._open_counts[key] = 1

    # seacheck: holds-lock
    def _locked_helper(self, key):
        self._access_clock[key] = 7

    def lock_free_read_is_fine(self, key):
        return self._open_counts.get(key)
