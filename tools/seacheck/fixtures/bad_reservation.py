"""Known-bad: reservation-pairing violations (rule a)."""


def leaked_forever(ledger, root, nbytes):
    # never committed, released, or handed off
    res = ledger.try_reserve(root, nbytes, capacity=100, required=10)
    if res is None:
        return False
    do_the_write(root)
    return True


def leaks_on_exception(ledger, tier, root, nbytes):
    # commit exists, but do_the_write can raise first and nothing
    # releases on the exception edge
    res = ledger.try_reserve(root, nbytes, capacity=100, required=10)
    do_the_write(root)
    ledger.commit(res, "key", nbytes)


def paired_correctly(ledger, root, nbytes):
    res = ledger.try_reserve(root, nbytes, capacity=100, required=10)
    if res is None:
        return 0
    try:
        do_the_write(root)
        ledger.commit(res, "key", nbytes)
    except Exception:
        ledger.release(res)
        raise
    return nbytes


def escapes_to_caller(ledger, root, nbytes):
    res = ledger.try_reserve(root, nbytes, capacity=100, required=10)
    return res


def suppressed_leak(ledger, root, nbytes):
    res = ledger.try_reserve(root, nbytes, capacity=100, required=10)  # seacheck: ignore[reservation-pairing]
    do_the_write(root)
    return True


def do_the_write(root):
    raise NotImplementedError
