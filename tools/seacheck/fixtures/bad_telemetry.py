"""Known-bad: telemetry-drift violations (rule d).

Linted as if it were ``src/repro/core/telemetry.py``: the COUNTERS table
registers a ghost, misses a field, and an increment targets an
unregistered name; ``snapshot`` ignores the registry.
"""

import threading
from dataclasses import dataclass, field

COUNTERS = {
    "flushed_bytes": "bytes flushed",
    "ghost_counter": "registered but not a field",
}


@dataclass
class Telemetry:
    flushed_bytes: int = 0
    unregistered_field: int = 0
    _lock: threading.Lock = field(default_factory=threading.Lock)

    def record_flush(self, nbytes):
        with self._lock:
            self.flushed_bytes += nbytes

    def record_sneaky(self):
        with self._lock:
            self.sneaky_counter += 1

    def snapshot(self):
        with self._lock:
            return {"flushed_bytes": self.flushed_bytes}
