"""pytest wiring for the runtime detector (``SEACHECK=1`` legs).

Activated from ``tests/conftest.py`` when ``SEACHECK=1``: installs the
lock instrumentation at configure time (before any test module imports
``repro``), drains findings after every test — failing the test that
produced them, so the offending schedule is named — and fails the session
if anything slips through teardown.
"""

from __future__ import annotations

import pytest

from . import runtime


def pytest_configure(config) -> None:
    runtime.install()
    config._seacheck_late_findings = []


@pytest.fixture(autouse=True)
def _seacheck_findings_guard():
    """Fail the test that produced a lock-order / held-across-fcntl
    finding (drained per-test so one bad test cannot poison the rest)."""
    yield
    found = runtime.drain_findings()
    if found:
        pytest.fail(
            "seacheck runtime findings:\n"
            + "\n".join(f.render() for f in found),
            pytrace=False,
        )


def pytest_sessionfinish(session, exitstatus) -> None:
    # teardown-time findings (daemon threads, atexit paths) bypass the
    # per-test fixture; surface them as a session failure
    late = runtime.drain_findings()
    if late:
        rep = session.config.pluginmanager.get_plugin("terminalreporter")
        if rep is not None:
            for f in late:
                rep.write_line(f.render(), red=True)
        session.exitstatus = 1
