"""seacheck rule registry.

Each rule module exposes ``RULE_ID`` (kebab-case), ``RULE_DOC`` (one-line
summary) and ``check(sf, tree) -> list[Violation]``. The engine parses each
file once, annotates parent links, and hands the tree to every rule.
"""

from __future__ import annotations

from .. import violations as _v
from . import (
    atomic_commit,
    invalidation,
    lock_discipline,
    reservation,
    telemetry_drift,
)

ALL_RULES = (
    reservation,
    atomic_commit,
    invalidation,
    telemetry_drift,
    lock_discipline,
)

for _mod in ALL_RULES:
    _v.RULES[_mod.RULE_ID] = _mod.RULE_DOC
