"""Rule ``atomic-commit``: files on tier roots may only appear via the
tmp + ``os.replace`` protocol (or an allowlisted journal writer).

The transfer engine's invariant (ARCHITECTURE.md "Data plane"): *a reader —
or a crash at any chunk boundary — can never observe a partially-written
file under any resolvable path.* A bare ``open(path, "w")``,
``shutil.copy*`` or ``np.save`` that targets a tier path breaks it: the
destination becomes resolvable at byte 0.

Scope: ``repro/core`` modules (the only code that touches real tier
paths). Flagged calls:

* builtin ``open`` / ``io.open`` / ``os.fdopen`` with a literal write/append
  mode (``w``, ``wb``, ``a``, ``x``, ``+``...) whose target does not
  mention a staging name (``tmp``/``TMP_SUFFIX``/``.sea_tmp``) — writes to
  a tmp name followed by ``os.replace`` are the sanctioned protocol;
* any ``shutil.copy``/``copyfile``/``copy2``/``copytree``/``move`` — byte
  movement belongs to the TransferEngine;
* ``np.save``/``numpy.save``/``savez`` — array bytes go through the mount
  (``fs.open``), never straight to a real path.

The mount-level ``self.open(...)`` / ``fs.open(...)`` API is exempt: it IS
the commit protocol (reservation + close-commit). The journal/ledger
writers built on ``os.open``+``os.pwrite`` under an fcntl lock are a
different, append-truncate protocol and are not produced by ``open()`` —
they never trip this rule.
"""

from __future__ import annotations

import ast

from ..astutil import call_name, identifier_fragments, qualname, string_fragments
from ..violations import SourceFile, Violation

RULE_ID = "atomic-commit"
RULE_DOC = (
    "tier-path writes must use tmp + os.replace (or an allowlisted "
    "journal writer)"
)

#: only the data-plane package creates files under tier roots
SCOPE_FRAGMENT = "repro/core/"

_WRITE_MODE_CHARS = ("w", "a", "x", "+")
_SHUTIL_COPIES = {"copy", "copyfile", "copy2", "copytree", "move"}
_NP_SAVES = {"save", "savez", "savez_compressed"}
#: receivers whose .open() is the mount API (SeaFS.open - the commit
#: protocol itself), not a raw file creation
_MOUNT_RECEIVERS = {"self", "fs", "seafs", "mount"}
_TMP_HINTS = ("tmp", "temp")


def _is_write_mode(call: ast.Call) -> bool:
    mode = None
    if len(call.args) >= 2:
        mode = call.args[1]
    for kw in call.keywords:
        if kw.arg == "mode":
            mode = kw.value
    if mode is None:
        return False  # default "r"
    if not (isinstance(mode, ast.Constant) and isinstance(mode.value, str)):
        return False  # dynamic mode: out of lexical reach
    return any(c in mode.value for c in _WRITE_MODE_CHARS)


def _expr_is_staging(target: ast.AST) -> bool:
    idents = [s.lower() for s in identifier_fragments(target)]
    if any(any(h in i for h in _TMP_HINTS) for i in idents):
        return True
    frags = [s.lower() for s in string_fragments(target)]
    return any(any(h in f for h in _TMP_HINTS) or ".sea_tmp" in f for f in frags)


def _dest_arg(call: ast.Call, pos: int, kwname: str) -> ast.AST | None:
    """The destination expression of a call: positional ``pos`` or the
    ``kwname`` keyword."""
    for kw in call.keywords:
        if kw.arg == kwname:
            return kw.value
    if len(call.args) > pos:
        return call.args[pos]
    return None


def _target_is_staging(call: ast.Call) -> bool:
    if not call.args:
        return False
    return _expr_is_staging(call.args[0])


def _receiver(call: ast.Call) -> str:
    f = call.func
    if isinstance(f, ast.Attribute):
        if isinstance(f.value, ast.Name):
            return f.value.id
        if isinstance(f.value, ast.Attribute):
            return f.value.attr
    return ""


def check(sf: SourceFile, tree: ast.AST) -> list[Violation]:
    if SCOPE_FRAGMENT not in sf.path:
        return []
    out: list[Violation] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = call_name(node)
        recv = _receiver(node)
        msg = None
        if name == "open" and recv in ("", "io", "os"):
            # builtin open / io.open / os.fdopen; the mount API
            # (self.open/fs.open) is the commit protocol itself
            if _is_write_mode(node) and not _target_is_staging(node):
                msg = (
                    "bare write-open can expose a partial file under a "
                    "resolvable path; stage to a *tmp* name and os.replace, "
                    "or go through the mount API (fs.open)"
                )
        elif name == "open" and recv in _MOUNT_RECEIVERS:
            pass
        elif recv == "shutil" and name in _SHUTIL_COPIES:
            # a copy whose DESTINATION is a staging name is one leg of the
            # sanctioned tmp + os.replace protocol, not a bypass
            dst = _dest_arg(node, 1, "dst")
            if dst is None or not _expr_is_staging(dst):
                msg = (
                    f"shutil.{name} bypasses the TransferEngine's "
                    "atomic-commit + admission protocol; use engine.copy / "
                    "fs.copyfile"
                )
        elif recv in ("np", "numpy") and name in _NP_SAVES:
            dst = _dest_arg(node, 0, "file")
            if dst is None or not _expr_is_staging(dst):
                msg = (
                    f"{recv}.{name} writes the destination in place; route "
                    "array bytes through the mount (fs.open) instead"
                )
        if msg is not None and not sf.suppressed(node.lineno, RULE_ID):
            out.append(
                Violation(RULE_ID, sf.path, node.lineno, qualname(node), msg)
            )
    return out
