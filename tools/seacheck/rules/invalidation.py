"""Rule ``invalidation-completeness``: replica-lifecycle sites must
invalidate the resolver — and, where the module is federation-aware,
publish/unpublish the registry — in the same function.

ARCHITECTURE.md ("Namespace resolver" / "Cluster federation"): the
invalidation list and the publish/unpublish list are *the same list by
construction*. PRs 3-7 each hand-fixed a site that moved/removed/created a
replica without telling the resolver (stale hits) or the registry (peers
pulling a ghost). This rule pins the construction.

Scope: the modules that orchestrate replica lifecycle AND own a resolver
reference (``seafs.py``, ``flusher.py``). A function is a *lifecycle site*
if it calls ``os.replace`` / ``os.remove`` / ``os.unlink`` / ``os.rename``
/ ``punch_hole`` on something that is not obviously non-replica machinery
(heartbeat/spool/journal/marker/tmp-reap paths, identified by the target
expression's identifiers). Such a function must also contain:

* a resolver maintenance call (``invalidate``/``invalidate_all``/
  ``note_location``/``refresh``), and
* a federation registry call (``_fed_*`` / ``publish`` / ``unpublish`` /
  ``unpublish_all`` / ``expunge``) when the module references federation
  at all.

Helpers whose *caller* owns the bookkeeping carry a per-line suppression
with a justification (grep ``seacheck: ignore[invalidation-completeness]``).
"""

from __future__ import annotations

import ast

from ..astutil import call_name, identifier_fragments, qualname, string_fragments
from ..violations import SourceFile, Violation

RULE_ID = "invalidation-completeness"
RULE_DOC = (
    "replica moves/removals must invalidate the resolver and update the "
    "federation registry in the same function"
)

#: modules that own resolver + federation references
SCOPE_SUFFIXES = ("repro/core/seafs.py", "repro/core/flusher.py")

_LIFECYCLE_OS = {"replace", "remove", "unlink", "rename"}
_LIFECYCLE_BARE = {"punch_hole"}
_RESOLVER_CALLS = {
    "invalidate",
    "invalidate_all",
    "note_location",
    "refresh",
}
_FED_CALLS = {
    "publish",
    "unpublish",
    "unpublish_all",
    "expunge",
    "retire",
}
#: target-identifier fragments that mark non-replica machinery files
_MACHINERY_HINTS = (
    "tmp",
    "temp",
    "heartbeat",
    "hb_",
    "spool",
    "journal",
    "marker",
    "manifest",
    "lock",
    "res_",
    ".res",
    "telemetry",
)


def _is_lifecycle_call(node: ast.Call) -> bool:
    f = node.func
    name = call_name(node)
    if (
        isinstance(f, ast.Attribute)
        and isinstance(f.value, ast.Name)
        and f.value.id == "os"
        and name in _LIFECYCLE_OS
    ):
        return True
    return name in _LIFECYCLE_BARE


def _targets_machinery(node: ast.Call) -> bool:
    idents = [s.lower() for s in identifier_fragments(node)]
    frags = [s.lower() for s in string_fragments(node)]
    for hint in _MACHINERY_HINTS:
        if any(hint in i for i in idents) or any(hint in f for f in frags):
            return True
    return False


def _module_is_federated(tree: ast.AST) -> bool:
    for node in ast.walk(tree):
        if isinstance(node, ast.Attribute) and (
            node.attr.startswith("_fed_") or node.attr in _FED_CALLS
        ):
            return True
        if isinstance(node, ast.Name) and node.id.startswith("_fed_"):
            return True
    return False


def check(sf: SourceFile, tree: ast.AST) -> list[Violation]:
    if not any(sf.path.endswith(s) for s in SCOPE_SUFFIXES):
        return []
    federated = _module_is_federated(tree)
    out: list[Violation] = []
    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        lifecycle: list[ast.Call] = []
        has_resolver = False
        has_fed = False
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            name = call_name(node)
            if _is_lifecycle_call(node) and not _targets_machinery(node):
                lifecycle.append(node)
            if name in _RESOLVER_CALLS:
                has_resolver = True
            if name.startswith("_fed_") or name in _FED_CALLS:
                has_fed = True
        if not lifecycle:
            continue
        site = lifecycle[0]
        missing = []
        if not has_resolver:
            missing.append("resolver invalidation")
        if federated and not has_fed:
            missing.append("federation publish/unpublish")
        if missing and not sf.suppressed(site.lineno, RULE_ID):
            out.append(
                Violation(
                    RULE_ID,
                    sf.path,
                    site.lineno,
                    qualname(site),
                    f"replica-lifecycle call without {' or '.join(missing)} "
                    "in the same function",
                )
            )
    return out
