"""Rule ``lock-discipline``: mutations of documented-guarded fields must
happen under their documented lock.

The guarded-state table below is transcribed from the modules' own
docstrings ("every field is guarded by ``lock``", "guards the model +
pending below", ...). For each configured module, any *mutation* of a
guarded attribute — assignment, augmented assignment, ``del``, or a
mutating method call (``append``/``pop``/``update``/...) — must be:

* lexically inside a ``with`` statement whose context expression contains
  one of the module's lock tokens (``self._lock``, ``acct.lock``,
  ``self._locked(`` — the fcntl-wrapping contextmanagers count: they take
  the thread lock), or
* inside a function annotated ``# seacheck: holds-lock`` (the caller holds
  the lock — the runtime layer is what actually verifies ownership), or
* inside ``__init__`` (construction precedes sharing).

Reads are deliberately NOT checked: the codebase has documented lock-free
read paths (``resolve_fast``, ``is_hot``, extent-validity probes) whose
whole point is mutating under the lock while probing without it.
"""

from __future__ import annotations

import ast

from ..astutil import enclosing_function, in_with_matching, qualname
from ..violations import SourceFile, Violation

RULE_ID = "lock-discipline"
RULE_DOC = "documented-guarded fields must be mutated under their lock"

#: module suffix -> (guarded attribute names, acceptable lock tokens)
GUARDED: dict[str, tuple[set[str], tuple[str, ...]]] = {
    "repro/core/seafs.py": (
        {"_open_counts", "_open_writers", "_access_clock", "_key_locks"},
        ("self._lock",),
    ),
    "repro/core/ledger.py": (
        {"files", "used", "reserved", "last_reconcile", "version"},
        (".lock", "._lock"),
    ),
    "repro/core/shared_ledger.py": (
        {"files", "used", "offset", "lines", "generation", "reconcile_ts"},
        (".lock", "._locked("),
    ),
    "repro/core/federation.py": (
        {"entries", "offset", "lines", "generation", "reconcile_ts"},
        (".lock", "._locked(", "._cache_lock"),
    ),
    "repro/core/flusher.py": (
        {"_pending", "_active", "_deferred", "_failed", "_inflight"},
        ("self._cv",),
    ),
    # _runs/_succ are deliberately absent: they are confined to the single
    # digestion thread (never touched under the lock), not lock-guarded
    "repro/core/prefetcher.py": (
        {"_pending", "_recent", "_inflight"},
        ("self._lock",),
    ),
    "repro/core/telemetry.py": (
        {"_locals"},
        ("self._lock",),
    ),
    "repro/core/extents.py": (
        {"valid", "_maps"},
        (".lock", "._lock"),
    ),
    # breaker state: every transition (open/half-open/close, probe claims,
    # window mutation) must happen inside the tracker's single lock
    "repro/core/health.py": (
        {"_roots", "br_state", "br_opened", "br_probe", "ev_window", "lat_sum", "lat_n"},
        ("self._lock",),
    ),
}

_MUTATING_METHODS = {
    "append",
    "appendleft",
    "add",
    "discard",
    "remove",
    "pop",
    "popleft",
    "popitem",
    "clear",
    "update",
    "setdefault",
    "extend",
    "move_to_end",
    "insert",
}


def _guarded_attr(node: ast.AST, fields: set[str]) -> ast.Attribute | None:
    """The guarded Attribute mutated by this target expression, if any.
    Matches ``X.field`` and ``X.field[...]``."""
    if isinstance(node, ast.Subscript):
        node = node.value
    if isinstance(node, ast.Attribute) and node.attr in fields:
        return node
    return None


def _mutations(tree: ast.AST, fields: set[str]):
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign):
            for t in node.targets:
                for el in t.elts if isinstance(t, ast.Tuple) else [t]:
                    a = _guarded_attr(el, fields)
                    if a is not None:
                        yield node, a
        elif isinstance(node, ast.AugAssign):
            a = _guarded_attr(node.target, fields)
            if a is not None:
                yield node, a
        elif isinstance(node, ast.Delete):
            for t in node.targets:
                a = _guarded_attr(t, fields)
                if a is not None:
                    yield node, a
        elif isinstance(node, ast.Call):
            f = node.func
            if isinstance(f, ast.Attribute) and f.attr in _MUTATING_METHODS:
                a = _guarded_attr(f.value, fields)
                if a is not None:
                    yield node, a


def check(sf: SourceFile, tree: ast.AST) -> list[Violation]:
    cfg = next(
        (v for suffix, v in GUARDED.items() if sf.path.endswith(suffix)), None
    )
    if cfg is None:
        return []
    fields, tokens = cfg
    out: list[Violation] = []
    for node, attr in _mutations(tree, fields):
        fn = enclosing_function(node)
        if fn is None:
            continue  # module-level initialisation
        if fn.name in ("__init__", "__new__"):
            continue
        if sf.holds_lock(fn.lineno):
            continue
        if in_with_matching(node, tokens):
            continue
        if sf.suppressed(node.lineno, RULE_ID):
            continue
        out.append(
            Violation(
                RULE_ID,
                sf.path,
                node.lineno,
                qualname(node),
                f"mutation of guarded field {attr.attr!r} outside "
                f"`with {tokens[0]}...` (annotate the function "
                "`# seacheck: holds-lock` if the caller holds it)",
            )
        )
    return out
