"""Rule ``reservation-pairing``: every ``try_reserve``/``reserve`` result
must be committed, released, or handed off on every path — including
exception edges.

The data plane's capacity invariant (``used + reserved <= capacity``,
ARCHITECTURE.md "Write commit protocol") only holds if no code path can
abandon an active reservation: a leaked one pins phantom budget against a
capped root until a reconcile expires it (in-process ledgers: forever).

Per call site ``res = <ledger>.try_reserve(...)`` the rule accepts:

* **escape** — ``res`` is returned/yielded, passed as a call argument
  (``commit_write(res, ...)``, ``tier.release_write(res)``), stored into an
  attribute/subscript, or swallowed into a comprehension: responsibility
  moved to the caller/owner, which this rule checks at *that* site.
* **resolution** — a ``res.release()`` / ``res.commit(...)`` method call,
  or ``res`` passed to a call whose name contains ``commit`` or
  ``release``.

and then requires that, when any *risky* statement (a call that may raise)
sits between the reservation and its resolution, at least one resolution
sits on an exception edge — a ``finally`` block or an ``except`` handler.
"""

from __future__ import annotations

import ast

from ..astutil import (
    ancestors,
    annotate_parents,  # noqa: F401  (re-exported for tests)
    call_name,
    enclosing_function,
    names_in,
    qualname,
)
from ..violations import SourceFile, Violation

RULE_ID = "reservation-pairing"
RULE_DOC = (
    "try_reserve results must be committed/released on all paths, "
    "including exception edges"
)

_RESERVE_NAMES = {"try_reserve", "reserve", "reserve_write"}
_RESOLVE_HINTS = ("commit", "release")


def _is_reserve_call(node: ast.AST) -> bool:
    return isinstance(node, ast.Call) and call_name(node) in _RESERVE_NAMES


def _on_exception_edge(node: ast.AST) -> bool:
    """Is ``node`` inside a ``finally`` block or an ``except`` handler?"""
    cur = node
    for anc in ancestors(node):
        if isinstance(anc, ast.Try) and any(
            cur is s or _contains(s, cur) for s in anc.finalbody
        ):
            return True
        if isinstance(anc, ast.ExceptHandler):
            return True
        cur = anc
    return False


def _contains(tree: ast.AST, target: ast.AST) -> bool:
    return any(n is target for n in ast.walk(tree))


def check(sf: SourceFile, tree: ast.AST) -> list[Violation]:
    out: list[Violation] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Assign) or not _is_reserve_call(node.value):
            continue
        if len(node.targets) != 1 or not isinstance(node.targets[0], ast.Name):
            continue  # tuple/attribute targets are an escape by storage
        var = node.targets[0].id
        fn = enclosing_function(node)
        if fn is None:
            continue
        # the ledger's own definition of try_reserve delegates to
        # _create_reservation; only *call sites* of the public API matter
        if fn.name in _RESERVE_NAMES:
            continue
        v = _analyze(sf, fn, node, var)
        if v is not None and not sf.suppressed(v.line, RULE_ID):
            out.append(v)
    return out


def _analyze(
    sf: SourceFile, fn: ast.AST, assign: ast.Assign, var: str
) -> Violation | None:
    resolutions: list[ast.Call] = []
    risky = False
    seen_assign = False
    for node in ast.walk(fn):
        if node is assign:
            seen_assign = True
            continue
        if isinstance(node, (ast.Return, ast.Yield, ast.YieldFrom)):
            if node.value is not None and var in names_in(node.value):
                return None  # escapes to the caller
        if isinstance(node, (ast.Assign, ast.AugAssign)):
            targets = (
                node.targets if isinstance(node, ast.Assign) else [node.target]
            )
            for t in targets:
                if not isinstance(t, ast.Name) and var in names_in(t):
                    return None  # stored into an attribute/subscript/container
        if isinstance(node, ast.Call):
            arg_names = set()
            for a in node.args:
                arg_names |= names_in(a)
            for kw in node.keywords:
                arg_names |= names_in(kw.value)
            name = call_name(node)
            if var in arg_names:
                if any(h in name for h in _RESOLVE_HINTS):
                    resolutions.append(node)
                else:
                    return None  # handed off to another callable
            elif (
                isinstance(node.func, ast.Attribute)
                and isinstance(node.func.value, ast.Name)
                and node.func.value.id == var
            ):
                resolutions.append(node)  # res.commit(...) / res.release()
            elif name not in _RESERVE_NAMES and not _is_trivial_call(node):
                risky = True
    if not seen_assign:  # pragma: no cover - walk always revisits assign
        return None
    line = assign.lineno
    sym = qualname(assign)
    if not resolutions:
        return Violation(
            RULE_ID,
            sf.path,
            line,
            sym,
            f"reservation {var!r} is never committed, released, or handed off",
        )
    if risky and not any(_on_exception_edge(r) for r in resolutions):
        return Violation(
            RULE_ID,
            sf.path,
            line,
            sym,
            f"reservation {var!r} can leak past an exception: no "
            "commit/release on a finally/except edge while other calls "
            "can raise",
        )
    return None


_TRIVIAL_CALLS = {
    "len",
    "max",
    "min",
    "int",
    "float",
    "str",
    "repr",
    "isinstance",
    "getattr",
}


def _is_trivial_call(node: ast.Call) -> bool:
    return call_name(node) in _TRIVIAL_CALLS
