"""Rule ``telemetry-drift``: the ``COUNTERS`` registry, the ``Telemetry``
dataclass fields, and the increments must all agree.

Generalizes the ``tests/test_docs.py`` config-drift gate to counters
(ISSUE 9 satellite): ``core/telemetry.py`` carries one canonical table —
``COUNTERS: {name: description}`` — that ``snapshot()`` iterates and this
rule cross-checks, so a counter can no longer be added, renamed, or
dropped in one place only.

Checks inside ``telemetry.py`` (all purely lexical — the CI lint job needs
no imports):

* every ``COUNTERS`` key is a ``Telemetry`` dataclass field;
* every public scalar (int/float) ``Telemetry`` field is in ``COUNTERS``;
* every ``self.<name> += ...`` inside ``Telemetry`` methods targets a
  registered counter;
* ``snapshot`` actually consumes ``COUNTERS`` (the registry must drive the
  export, not decorate it).

Check everywhere else: counters are mutated only through
``Telemetry.record_*`` methods — a ``<x>.telemetry.<counter> += ...`` spot
increment bypasses the lock and the registry and is flagged.
"""

from __future__ import annotations

import ast

from ..astutil import qualname
from ..violations import SourceFile, Violation

RULE_ID = "telemetry-drift"
RULE_DOC = (
    "every incremented Telemetry counter must be registered in COUNTERS "
    "and vice versa"
)

TELEMETRY_SUFFIX = "repro/core/telemetry.py"


def _find_class(tree: ast.AST, name: str) -> ast.ClassDef | None:
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and node.name == name:
            return node
    return None


def _counters_table(tree: ast.AST) -> tuple[dict[str, int], int]:
    """``{counter_name: lineno}`` from the module-level COUNTERS dict
    literal, plus the table's own line (0 when absent)."""
    for node in ast.walk(tree):
        target = None
        if isinstance(node, ast.AnnAssign) and isinstance(
            node.target, ast.Name
        ):
            target = node.target.id
            value = node.value
        elif isinstance(node, ast.Assign) and len(node.targets) == 1 and (
            isinstance(node.targets[0], ast.Name)
        ):
            target = node.targets[0].id
            value = node.value
        if target != "COUNTERS":
            continue
        if not isinstance(value, ast.Dict):
            return {}, node.lineno
        out = {}
        for k in value.keys:
            if isinstance(k, ast.Constant) and isinstance(k.value, str):
                out[k.value] = k.lineno
        return out, node.lineno
    return {}, 0


def _scalar_fields(cls: ast.ClassDef) -> dict[str, int]:
    """Public dataclass fields annotated int/float -> lineno."""
    out = {}
    for stmt in cls.body:
        if not isinstance(stmt, ast.AnnAssign):
            continue
        if not isinstance(stmt.target, ast.Name):
            continue
        name = stmt.target.id
        if name.startswith("_"):
            continue
        ann = stmt.annotation
        if isinstance(ann, ast.Name) and ann.id in ("int", "float"):
            out[name] = stmt.lineno
    return out


def _self_increments(cls: ast.ClassDef) -> list[tuple[str, int]]:
    out = []
    for node in ast.walk(cls):
        if not isinstance(node, ast.AugAssign):
            continue
        t = node.target
        if (
            isinstance(t, ast.Attribute)
            and isinstance(t.value, ast.Name)
            and t.value.id == "self"
        ):
            out.append((t.attr, node.lineno))
    return out


def _check_telemetry_module(sf: SourceFile, tree: ast.AST) -> list[Violation]:
    out: list[Violation] = []

    def flag(line: int, sym: str, msg: str) -> None:
        if not sf.suppressed(line, RULE_ID):
            out.append(Violation(RULE_ID, sf.path, line, sym, msg))

    counters, table_line = _counters_table(tree)
    cls = _find_class(tree, "Telemetry")
    if table_line == 0:
        flag(1, "<module>", "no COUNTERS registry table found")
        return out
    if cls is None:  # pragma: no cover - telemetry.py always has the class
        return out
    fields = _scalar_fields(cls)
    for name, line in counters.items():
        if name not in fields:
            flag(
                line,
                "COUNTERS",
                f"registered counter {name!r} is not a Telemetry field",
            )
    for name, line in fields.items():
        if name not in counters:
            flag(
                line,
                f"Telemetry.{name}",
                f"Telemetry field {name!r} is not registered in COUNTERS",
            )
    for name, line in _self_increments(cls):
        if not name.startswith("_") and name not in counters:
            flag(
                line,
                f"Telemetry.{name}",
                f"increment of unregistered counter {name!r}",
            )
    snapshot = next(
        (
            n
            for n in cls.body
            if isinstance(n, ast.FunctionDef) and n.name == "snapshot"
        ),
        None,
    )
    if snapshot is not None and not any(
        isinstance(n, ast.Name) and n.id == "COUNTERS"
        for n in ast.walk(snapshot)
    ):
        flag(
            snapshot.lineno,
            "Telemetry.snapshot",
            "snapshot() does not iterate the COUNTERS registry",
        )
    return out


def _check_other_module(sf: SourceFile, tree: ast.AST) -> list[Violation]:
    out: list[Violation] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.AugAssign):
            continue
        t = node.target
        if not isinstance(t, ast.Attribute):
            continue
        recv = t.value
        is_telemetry = (
            isinstance(recv, ast.Name) and recv.id == "telemetry"
        ) or (isinstance(recv, ast.Attribute) and recv.attr == "telemetry")
        if is_telemetry and not sf.suppressed(node.lineno, RULE_ID):
            out.append(
                Violation(
                    RULE_ID,
                    sf.path,
                    node.lineno,
                    qualname(node),
                    f"ad-hoc increment of telemetry.{t.attr}; add a "
                    "Telemetry.record_* method (lock + registry) instead",
                )
            )
    return out


def check(sf: SourceFile, tree: ast.AST) -> list[Violation]:
    if sf.path.endswith(TELEMETRY_SUFFIX):
        return _check_telemetry_module(sf, tree)
    return _check_other_module(sf, tree)
