"""Runtime lock-order / race detector (layer 2, opt-in via ``SEACHECK=1``).

:func:`install` monkeypatches ``threading.Lock`` / ``threading.RLock`` so
that locks *created from* ``repro/core`` modules are wrapped in an
instrumented proxy. Each acquisition records:

* the per-thread **held-lock stack**;
* a global **site-order graph**: creation site A -> creation site B
  whenever a lock born at B is acquired while one born at A is held. A
  cycle in this graph is a potential deadlock (thread 1 takes A then B,
  thread 2 takes B then A) and is reported even if the schedules never
  actually collide in the run;
* for locks born at the *same* site (the per-key RLock pool, the ledger's
  per-root locks), the **instance-pair order**: acquiring instance x then
  y and elsewhere y then x is the classic ABBA inversion the sorted-key
  two-lock protocol in ``SeaFS.rename``/``copyfile`` exists to prevent;
* blocking ``fcntl.flock``/``fcntl.lockf`` calls made while instrumented
  locks are held (cross-process waits under an in-process lock), unless
  the calling function is in :data:`FCNTL_ALLOWLIST`.

Findings accumulate in-process; the pytest plugin drains them after every
test and fails the test that produced them.

``install()`` must run **before** ``repro`` modules import: dataclass
``field(default_factory=threading.Lock)`` (telemetry) binds the factory at
class-creation time, so late installation leaves those locks dark.

Overhead is bounded: one dict/list update under one global bookkeeping
lock per acquire/release. ``benchmarks/seacheck_bench.py`` gates the
instrumented tier-1 subset at < 2x the uninstrumented wall-clock.
"""

from __future__ import annotations

import fcntl
import os
import sys
import threading
from dataclasses import dataclass, field

#: (file basename, function name) pairs allowed to block in fcntl while
#: holding an instrumented lock — each is a documented thread-lock +
#: fcntl-lock pairing where the thread lock serializes this process's fd
#: (POSIX locks are per (process, inode)) and the fcntl wait is the
#: cross-process admission; the thread lock is never waited on by a
#: holder of the fcntl lock, so the pairing cannot deadlock.
FCNTL_ALLOWLIST = {
    ("shared_ledger.py", "_locked"),
    ("federation.py", "_locked"),
}

#: source-path fragments whose lock creations get instrumented
DEFAULT_PATH_FRAGMENTS = ("repro/core",)


@dataclass
class Finding:
    kind: str      # "lock-order-cycle" | "lock-order-inversion" | "held-across-fcntl"
    message: str
    sites: tuple[str, ...] = ()
    thread: str = ""

    def render(self) -> str:
        where = f" [{' -> '.join(self.sites)}]" if self.sites else ""
        return f"seacheck.runtime: {self.kind}: {self.message}{where}"


class _Held:
    __slots__ = ("lock", "count")

    def __init__(self, lock):
        self.lock = lock
        self.count = 1


@dataclass
class _State:
    """All detector bookkeeping, behind one (uninstrumented) lock."""

    guard: threading.Lock = field(default_factory=threading.Lock)
    edges: dict[str, set[str]] = field(default_factory=dict)
    #: (site, id_lo, id_hi) -> first observed direction (True = lo first)
    pair_order: dict[tuple[str, int, int], bool] = field(default_factory=dict)
    findings: list[Finding] = field(default_factory=list)
    reported_cycles: set[frozenset[str]] = field(default_factory=set)
    reported_pairs: set[tuple[str, int, int]] = field(default_factory=set)
    reported_fcntl: set[str] = field(default_factory=set)


_state = _State()
_tls = threading.local()
_installed = False
_orig: dict[str, object] = {}


def _held_stack() -> list[_Held]:
    stack = getattr(_tls, "held", None)
    if stack is None:
        stack = _tls.held = []
    return stack


# -- graph bookkeeping -------------------------------------------------------
def _find_path(src: str, dst: str) -> list[str] | None:
    """DFS path src -> dst in the site-order graph (caller holds guard)."""
    seen = {src}
    stack = [(src, [src])]
    while stack:
        node, path = stack.pop()
        if node == dst:
            return path
        for nxt in _state.edges.get(node, ()):
            if nxt not in seen:
                seen.add(nxt)
                stack.append((nxt, path + [nxt]))
    return None


def _note_acquire(lock: "_WrappedLock", count: int = 1) -> None:
    stack = _held_stack()
    for rec in stack:
        if rec.lock is lock:
            rec.count += count
            return
    tname = threading.current_thread().name
    with _state.guard:
        for rec in stack:
            a, b = rec.lock.site, lock.site
            if a == b:
                if rec.lock is not lock:
                    _note_same_site_pair(a, rec.lock, lock, tname)
                continue
            added = b not in _state.edges.setdefault(a, set())
            if added:
                _state.edges[a].add(b)
                # a fresh a->b edge closes a cycle iff b already reaches a
                path = _find_path(b, a)
                if path is not None:
                    cycle = frozenset(path)
                    if cycle not in _state.reported_cycles:
                        _state.reported_cycles.add(cycle)
                        _state.findings.append(
                            Finding(
                                "lock-order-cycle",
                                "lock acquisition order forms a cycle "
                                "(potential deadlock)",
                                sites=tuple(path + [path[0]]),
                                thread=tname,
                            )
                        )
    stack.append(_Held(lock))
    if count > 1:
        stack[-1].count = count


def _note_same_site_pair(site, held, acquired, tname: str) -> None:
    """Two distinct instances from one creation site (caller holds guard):
    the per-key/per-root lock pools. Record the id-order direction; seeing
    both directions is an ABBA inversion."""
    lo, hi = sorted((id(held), id(acquired)))
    key = (site, lo, hi)
    direction = id(held) == lo
    first = _state.pair_order.setdefault(key, direction)
    if first != direction and key not in _state.reported_pairs:
        _state.reported_pairs.add(key)
        _state.findings.append(
            Finding(
                "lock-order-inversion",
                "two locks from one creation site acquired in both "
                "orders (ABBA; acquire in a canonical — e.g. sorted-key "
                "— order)",
                sites=(site, site),
                thread=tname,
            )
        )


def _note_release(lock: "_WrappedLock") -> None:
    stack = _held_stack()
    for i in range(len(stack) - 1, -1, -1):
        if stack[i].lock is lock:
            stack[i].count -= 1
            if stack[i].count <= 0:
                del stack[i]
            return


def _forget(lock: "_WrappedLock") -> int:
    """Remove every recursion level of ``lock`` from the held stack
    (Condition.wait's _release_save); returns the forgotten count."""
    stack = _held_stack()
    for i in range(len(stack) - 1, -1, -1):
        if stack[i].lock is lock:
            count = stack[i].count
            del stack[i]
            return count
    return 0


# -- instrumented proxies ----------------------------------------------------
class _WrappedLock:
    _real_factory = staticmethod(threading.Lock)

    __slots__ = ("_lock", "site")

    def __init__(self, site: str):
        self._lock = type(self)._real_factory()
        self.site = site

    def acquire(self, blocking: bool = True, timeout: float = -1):
        got = self._lock.acquire(blocking, timeout)
        if got:
            _note_acquire(self)
        return got

    def release(self) -> None:
        _note_release(self)
        self._lock.release()

    def locked(self) -> bool:
        fn = getattr(self._lock, "locked", None)
        return bool(fn()) if fn is not None else False

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def __repr__(self) -> str:  # pragma: no cover
        return f"<seacheck {type(self).__name__} site={self.site}>"


class _WrappedRLock(_WrappedLock):
    _real_factory = staticmethod(threading.RLock)

    __slots__ = ()

    # Condition-protocol delegation (threading.Condition(wrapped_rlock))
    def _is_owned(self):
        return self._lock._is_owned()

    def _release_save(self):
        count = _forget(self)
        return (self._lock._release_save(), count)

    def _acquire_restore(self, state):
        real_state, count = state
        self._lock._acquire_restore(real_state)
        _note_acquire(self, count=max(count, 1))


def _creation_frame(depth: int = 2):
    """First caller frame with a real source file. Skips synthetic frames
    (``<string>``): a dataclass ``field(default_factory=threading.Lock)``
    fires from the exec-generated ``__init__``, and the interesting caller
    is whoever constructed the dataclass."""
    f = sys._getframe(depth)
    while f is not None and f.f_code.co_filename.startswith("<"):
        f = f.f_back
    return f


def _make_factory(wrapper_cls, original, fragments):
    def factory():
        f = _creation_frame(2)
        if f is not None:
            fname = f.f_code.co_filename.replace(os.sep, "/")
            if any(frag in fname for frag in fragments):
                short = "/".join(fname.rsplit("/", 2)[-2:])
                return wrapper_cls(f"{short}:{f.f_lineno}")
        return original()

    factory._seacheck_original = original  # type: ignore[attr-defined]
    return factory


def instrumented_lock(site: str, *, rlock: bool = False) -> _WrappedLock:
    """An always-instrumented lock for tests and fixtures."""
    return (_WrappedRLock if rlock else _WrappedLock)(site)


# -- fcntl interposition -----------------------------------------------------
def _blocking_lock_op(op: int) -> bool:
    return bool(op & (fcntl.LOCK_EX | fcntl.LOCK_SH)) and not (
        op & fcntl.LOCK_NB
    )


def _fcntl_caller_allowlisted() -> bool:
    f = sys._getframe(2)
    while f is not None:
        code = f.f_code
        fname = code.co_filename.replace(os.sep, "/")
        if "seacheck" not in fname:
            return (os.path.basename(fname), code.co_name) in FCNTL_ALLOWLIST
        f = f.f_back
    return False  # pragma: no cover


def _note_fcntl(kind: str) -> None:
    stack = _held_stack()
    if not stack or _fcntl_caller_allowlisted():
        return
    held_sites = tuple(rec.lock.site for rec in stack)
    tname = threading.current_thread().name
    with _state.guard:
        key = f"{kind}@{held_sites}"
        if key in _state.reported_fcntl:
            return
        _state.reported_fcntl.add(key)
        _state.findings.append(
            Finding(
                "held-across-fcntl",
                f"blocking {kind} while holding in-process lock(s) — a "
                "cross-process wait under a thread lock (allowlist the "
                "site in FCNTL_ALLOWLIST only with a written deadlock "
                "argument)",
                sites=held_sites,
                thread=tname,
            )
        )


def _wrap_flock(orig):
    def flock(fd, operation):
        if _blocking_lock_op(operation):
            _note_fcntl("fcntl.flock")
        return orig(fd, operation)

    flock._seacheck_original = orig  # type: ignore[attr-defined]
    return flock


def _wrap_lockf(orig):
    def lockf(fd, cmd, *args):
        if _blocking_lock_op(cmd):
            _note_fcntl("fcntl.lockf")
        return orig(fd, cmd, *args)

    lockf._seacheck_original = orig  # type: ignore[attr-defined]
    return lockf


# -- lifecycle ---------------------------------------------------------------
def install(path_fragments: tuple[str, ...] = DEFAULT_PATH_FRAGMENTS) -> None:
    """Patch the lock factories and fcntl. Idempotent. Must run before
    ``repro`` imports (dataclass default_factory binds at class creation)."""
    global _installed
    if _installed:
        return
    _installed = True
    _orig["Lock"] = threading.Lock
    _orig["RLock"] = threading.RLock
    _orig["flock"] = fcntl.flock
    _orig["lockf"] = fcntl.lockf
    threading.Lock = _make_factory(  # type: ignore[misc]
        _WrappedLock, _orig["Lock"], path_fragments
    )
    threading.RLock = _make_factory(  # type: ignore[misc]
        _WrappedRLock, _orig["RLock"], path_fragments
    )
    fcntl.flock = _wrap_flock(_orig["flock"])
    fcntl.lockf = _wrap_lockf(_orig["lockf"])


def uninstall() -> None:
    global _installed
    if not _installed:
        return
    _installed = False
    threading.Lock = _orig.pop("Lock")  # type: ignore[misc]
    threading.RLock = _orig.pop("RLock")  # type: ignore[misc]
    fcntl.flock = _orig.pop("flock")
    fcntl.lockf = _orig.pop("lockf")


def installed() -> bool:
    return _installed


def findings() -> list[Finding]:
    with _state.guard:
        return list(_state.findings)


def drain_findings() -> list[Finding]:
    with _state.guard:
        out = list(_state.findings)
        _state.findings.clear()
        return out


def reset() -> None:
    """Clear the order graphs AND findings (test isolation)."""
    with _state.guard:
        _state.edges.clear()
        _state.pair_order.clear()
        _state.findings.clear()
        _state.reported_cycles.clear()
        _state.reported_pairs.clear()
        _state.reported_fcntl.clear()
