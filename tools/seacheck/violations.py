"""Violation model, suppression comments, and the accepted-violations baseline.

A violation is identified for baseline purposes by ``(rule, path, symbol)``
— NOT by line number — so unrelated edits above a baselined site do not
resurrect it and force baseline churn. ``symbol`` is the dotted qualname of
the enclosing function/class (module-level code uses ``<module>``).

Suppression is per-line: a trailing ``# seacheck: ignore[rule-id]`` (or the
blanket ``# seacheck: ignore``) on the flagged line silences it.  A
function-level ``# seacheck: holds-lock`` annotation on (or immediately
above) a ``def`` line asserts that every mutation inside the function runs
with the relevant lock already held by the caller — the lexical
lock-discipline rule trusts it, and the runtime layer is what actually
verifies lock ownership.
"""

from __future__ import annotations

import json
import re
from dataclasses import dataclass, field

#: rule id -> short human name (filled by rules/__init__ registration)
RULES: dict[str, str] = {}

_IGNORE_RE = re.compile(r"#\s*seacheck:\s*ignore(?:\[([a-z0-9-]+)\])?")
_HOLDS_LOCK_RE = re.compile(r"#\s*seacheck:\s*holds-lock\b")


@dataclass(frozen=True)
class Violation:
    rule: str        # e.g. "reservation-pairing"
    path: str        # repo-relative posix path
    line: int        # 1-based line of the offending node
    symbol: str      # dotted qualname of the enclosing def/class
    message: str

    def key(self) -> tuple[str, str, str]:
        return (self.rule, self.path, self.symbol)

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.symbol}: {self.message}"


@dataclass
class SourceFile:
    """One parsed module plus the per-line suppression table."""

    path: str                    # repo-relative posix path
    source: str
    lines: list[str] = field(default_factory=list)

    def __post_init__(self) -> None:
        self.lines = self.source.splitlines()

    def suppressed(self, line: int, rule: str) -> bool:
        if not 1 <= line <= len(self.lines):
            return False
        m = _IGNORE_RE.search(self.lines[line - 1])
        if m is None:
            return False
        return m.group(1) is None or m.group(1) == rule

    def holds_lock(self, def_line: int) -> bool:
        """True when the ``def`` at ``def_line`` carries (or is preceded
        by) a ``# seacheck: holds-lock`` annotation. Decorator and
        comment lines between the annotation and the ``def`` are
        skipped, so the annotation sits naturally above a decorated
        method."""
        ln = def_line
        while 1 <= ln <= len(self.lines):
            text = self.lines[ln - 1]
            if _HOLDS_LOCK_RE.search(text):
                return True
            stripped = text.strip()
            if ln != def_line and not (
                stripped.startswith("@") or stripped.startswith("#")
            ):
                return False
            ln -= 1
        return False


def load_baseline(path: str) -> dict[tuple[str, str, str], str]:
    """``{(rule, path, symbol): reason}`` from the baseline JSON file."""
    try:
        with open(path) as f:
            entries = json.load(f)
    except FileNotFoundError:
        return {}
    out = {}
    for e in entries:
        out[(e["rule"], e["path"], e["symbol"])] = e.get("reason", "")
    return out


def filter_baselined(
    violations: list[Violation], baseline: dict[tuple[str, str, str], str]
) -> tuple[list[Violation], list[tuple[str, str, str]]]:
    """Split out baselined violations; also return baseline entries that no
    longer match anything (stale entries should be pruned, not hoarded)."""
    live_keys = {v.key() for v in violations}
    fresh = [v for v in violations if v.key() not in baseline]
    stale = [k for k in baseline if k not in live_keys]
    return fresh, stale
